//! Work splitting for the native kernels: a persistent worker pool with
//! a shared work queue — no deps, no threads spawned on the hot path.
//!
//! Every parallel kernel in [`super::linalg`] and [`super::kernels`]
//! funnels through [`par_rows`]: the output buffer is split into
//! contiguous chunks of whole rows (a "row" being whatever unit the
//! kernel parallelizes over — a GEMM output row, a ball, a selection
//! group), each chunk becomes one job on the [`WorkerPool`]'s queue, and
//! the closure computes its rows exactly like the serial `*_reference`
//! twin would. Because chunks are contiguous and each output element's
//! accumulation order is untouched, the parallel kernels are bitwise
//! equal to their scalar twins — the property `rust/tests/conformance.rs`
//! enforces. Which worker executes which chunk never affects the result,
//! so the pool's scheduling freedom is invisible to the numerics.
//!
//! # Pool lifecycle
//!
//! The free [`par_rows`] dispatches on a lazily-created process-wide
//! pool ([`global_pool`]): workers are spawned on demand up to the
//! **aggregate** budget of every dispatch currently in flight — so
//! concurrent forwards (the router's worker pool) each get their
//! requested parallelism, never more than [`MAX_THREADS`] total — park
//! on a condvar when the queue is empty, and are reused across every
//! kernel call for the life of the process; construction/drop churn of
//! backends never spawns or leaks threads. Explicit pools
//! ([`WorkerPool::new`]) signal shutdown and **join every worker on
//! drop**; `rust/tests/conformance.rs` asserts both properties (bitwise
//! stability across 100+ reused dispatches, and a zero live-worker gauge
//! after drop).
//!
//! # Dispatch + completion
//!
//! A `par_rows` call enqueues `chunks - 1` lifetime-erased jobs, runs
//! the **last** chunk inline on the caller's thread, then waits on a
//! completion latch. The erasure is sound for the same reason
//! `std::thread::scope` is: the latch is not released until every job
//! has finished touching the borrowed closure/output, so `par_rows`
//! cannot return (or unwind — inline-chunk panics are caught and
//! re-thrown after the wait) while a worker still holds a borrow. While
//! waiting, the caller *helps*: it pops and runs queued jobs instead of
//! blocking, so nested `par_rows` calls — e.g. the head-parallel
//! attention in [`super::native`] running row-parallel GEMMs inside its
//! per-head jobs — can never deadlock the pool, even when every worker
//! is itself waiting on an inner dispatch. Job panics are captured and
//! resumed on the caller, matching scoped-spawn semantics.
//!
//! Thread-count resolution (see [`resolve_threads`]): an explicit
//! request wins, then the `BSA_NATIVE_THREADS` environment override,
//! then `std::thread::available_parallelism()`. The resolved count is an
//! upper bound — `par_rows` never uses more workers than it has rows,
//! and a count of 1 runs inline with zero dispatch overhead.
//!
//! The previous implementation spawned scoped threads per call;
//! [`par_rows_scoped`] retains it verbatim as the differential oracle
//! for the pool dispatcher and as the comparator in the spawn-overhead
//! microbench (`cargo bench --bench paper -- bsa_native`, the
//! `pool_dispatch` section of `BENCH_native.json`).

use std::any::Any;
use std::collections::VecDeque;
use std::ops::Range;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::{JoinHandle, Thread};

/// Hard upper bound on kernel threads (sanity cap for typo'd overrides;
/// also the ceiling on the global pool's worker population).
pub const MAX_THREADS: usize = 64;

/// Name of the environment override consulted by [`resolve_threads`].
pub const THREADS_ENV: &str = "BSA_NATIVE_THREADS";

fn hardware_threads() -> usize {
    static HW: OnceLock<usize> = OnceLock::new();
    *HW.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Resolve a kernel thread count: `requested > 0` wins, else the
/// `BSA_NATIVE_THREADS` env var (if set to a positive integer), else the
/// machine's available parallelism. Always in `1..=MAX_THREADS`.
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested.min(MAX_THREADS);
    }
    if let Ok(s) = std::env::var(THREADS_ENV) {
        if let Ok(t) = s.trim().parse::<usize>() {
            if t > 0 {
                return t.min(MAX_THREADS);
            }
        }
    }
    hardware_threads().min(MAX_THREADS)
}

/// Split `rows` items into at most `threads` contiguous, near-equal
/// ranges covering `0..rows` in order (the chunking [`par_rows`] uses).
pub fn chunk_rows(rows: usize, threads: usize) -> Vec<Range<usize>> {
    let t = threads.max(1).min(rows.max(1));
    let per = (rows + t - 1) / t;
    let mut out = Vec::new();
    let mut start = 0;
    while start < rows {
        let end = (start + per).min(rows);
        out.push(start..end);
        start = end;
    }
    out
}

/// A queued unit of work: one chunk closure from a `par_rows` dispatch,
/// lifetime-erased (see the SAFETY argument at the erasure site).
type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    work_ready: Condvar,
    /// Workers currently executing a job (excludes help-while-waiting
    /// callers — this gauges the worker population, not total progress).
    busy: AtomicUsize,
}

/// Completion latch for one `par_rows` dispatch. Modeled on
/// `std::thread::scope`'s internals: an atomic countdown plus
/// park/unpark, so the last job's final action is an `unpark` on a
/// *cloned* thread handle — after the decrement that releases the
/// caller, a job never touches the latch again, which is what makes it
/// sound to keep the latch on the caller's stack.
struct Latch {
    remaining: AtomicUsize,
    caller: Thread,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl Latch {
    fn new(jobs: usize) -> Latch {
        Latch {
            remaining: AtomicUsize::new(jobs),
            caller: std::thread::current(),
            panic: Mutex::new(None),
        }
    }

    /// Called exactly once by each job, as its very last action.
    fn complete(&self, panicked: Option<Box<dyn Any + Send>>) {
        if let Some(p) = panicked {
            *self.panic.lock().unwrap() = Some(p);
        }
        // Clone the handle BEFORE the decrement: the moment `remaining`
        // hits zero the caller may return from `wait` and free the latch.
        let caller = self.caller.clone();
        if self.remaining.fetch_sub(1, Ordering::Release) == 1 {
            caller.unpark();
        }
    }

    /// Wait until every job has completed, then re-throw the first
    /// captured job panic. Instead of blocking outright, the caller
    /// *helps*: any queued job (from this or any other dispatch on
    /// `pool`) is popped and run, which keeps nested dispatches
    /// deadlock-free — a waiter's own queued jobs are always runnable by
    /// the waiter itself. `park` is wrapped in a re-check loop, so
    /// spurious wakeups and stale unpark tokens are harmless.
    fn wait(&self, pool: &WorkerPool) {
        while self.remaining.load(Ordering::Acquire) != 0 {
            match pool.try_pop() {
                Some(job) => {
                    // par_rows jobs catch their own panics; this outer
                    // catch only shields the waiter from raw panics.
                    let _ = std::panic::catch_unwind(AssertUnwindSafe(job));
                }
                None => std::thread::park(),
            }
        }
        if let Some(p) = self.panic.lock().unwrap().take() {
            std::panic::resume_unwind(p);
        }
    }
}

/// A persistent pool of parked worker threads executing [`par_rows`]
/// chunk jobs from a shared FIFO queue.
///
/// The free [`par_rows`] uses the lazily-created [`global_pool`]; an
/// explicit `WorkerPool` is useful for lifecycle tests and embedders
/// that want ownership. Dropping a pool signals shutdown, drains the
/// queue, and joins every worker — no thread outlives its pool.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    /// Live-worker gauge (incremented at spawn, decremented on worker
    /// exit via a drop guard, so even a panicking worker counts down).
    live: Arc<AtomicUsize>,
    /// Sum of the worker demand (`threads - 1`) of every dispatch
    /// currently in flight: concurrent `par_rows` callers grow the pool
    /// to their *aggregate* demand (capped at [`MAX_THREADS`]), not just
    /// the largest single budget — otherwise multi-worker serving would
    /// contend for a pool sized to one forward pass.
    inflight: AtomicUsize,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

/// Decrements the in-flight demand on drop, so a dispatch that unwinds
/// (job or inline-chunk panic) still releases its claim.
struct InflightGuard<'a>(&'a AtomicUsize, usize);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(self.1, Ordering::Relaxed);
    }
}

fn worker_main(shared: Arc<PoolShared>, live: Arc<AtomicUsize>) {
    struct Gauge(Arc<AtomicUsize>);
    impl Drop for Gauge {
        fn drop(&mut self) {
            self.0.fetch_sub(1, Ordering::Release);
        }
    }
    let _gauge = Gauge(live);
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    break j;
                }
                if st.shutdown {
                    return;
                }
                st = shared.work_ready.wait(st).unwrap();
            }
        };
        // Keep the worker alive across any panicking job (par_rows jobs
        // catch their own panics and report through the latch; this is
        // the backstop for everything else).
        struct Busy<'a>(&'a AtomicUsize);
        impl Drop for Busy<'_> {
            fn drop(&mut self) {
                self.0.fetch_sub(1, Ordering::Relaxed);
            }
        }
        shared.busy.fetch_add(1, Ordering::Relaxed);
        let _busy = Busy(&shared.busy);
        let _ = std::panic::catch_unwind(AssertUnwindSafe(job));
    }
}

impl WorkerPool {
    /// Create a pool with `workers` threads parked and ready (capped at
    /// [`MAX_THREADS`]). `0` starts empty; [`par_rows`](Self::par_rows)
    /// grows the pool on demand.
    pub fn new(workers: usize) -> WorkerPool {
        let pool = WorkerPool {
            shared: Arc::new(PoolShared {
                state: Mutex::new(PoolState { jobs: VecDeque::new(), shutdown: false }),
                work_ready: Condvar::new(),
                busy: AtomicUsize::new(0),
            }),
            live: Arc::new(AtomicUsize::new(0)),
            inflight: AtomicUsize::new(0),
            handles: Mutex::new(Vec::new()),
        };
        pool.ensure_workers(workers);
        pool
    }

    /// Grow the worker population to at least `target` threads (capped
    /// at [`MAX_THREADS`]); never shrinks.
    fn ensure_workers(&self, target: usize) {
        let target = target.min(MAX_THREADS);
        // cheap read first: the common case is an already-warm pool
        if self.live.load(Ordering::Relaxed) >= target {
            return;
        }
        let mut handles = self.handles.lock().unwrap();
        while handles.len() < target {
            let shared = self.shared.clone();
            let live = self.live.clone();
            self.live.fetch_add(1, Ordering::Relaxed);
            let h = std::thread::Builder::new()
                .name(format!("bsa-pool-{}", handles.len()))
                .spawn(move || worker_main(shared, live))
                .expect("spawn bsa-pool worker");
            handles.push(h);
        }
    }

    /// Number of worker threads ever spawned (the pool never shrinks
    /// before drop).
    pub fn worker_count(&self) -> usize {
        self.handles.lock().unwrap().len()
    }

    /// Worker threads currently alive.
    pub fn live_workers(&self) -> usize {
        self.live.load(Ordering::Acquire)
    }

    /// Clonable live-worker gauge that stays readable after the pool is
    /// dropped — `Drop` joins every worker, so the gauge must read 0 the
    /// moment `drop` returns (asserted by the conformance suite).
    pub fn live_gauge(&self) -> Arc<AtomicUsize> {
        self.live.clone()
    }

    /// Jobs currently queued and not yet picked up (instantaneous).
    pub fn queue_depth(&self) -> usize {
        self.shared.state.lock().unwrap().jobs.len()
    }

    /// Workers currently executing a job (instantaneous; excludes
    /// help-while-waiting callers running jobs on their own threads).
    pub fn busy_workers(&self) -> usize {
        self.shared.busy.load(Ordering::Relaxed)
    }

    /// Aggregate worker demand (`threads - 1` per dispatch) of every
    /// `par_rows` call currently in flight.
    pub fn inflight_demand(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    fn push_job(&self, job: Job) {
        let mut st = self.shared.state.lock().unwrap();
        st.jobs.push_back(job);
        drop(st);
        self.shared.work_ready.notify_one();
    }

    fn try_pop(&self) -> Option<Job> {
        self.shared.state.lock().unwrap().jobs.pop_front()
    }

    /// Run `f(first_row, chunk)` over disjoint contiguous whole-row
    /// chunks of `out` (`row_width` elements per row), one chunk per
    /// queued job. The chunks are exactly [`chunk_rows`]`(rows,
    /// threads)`; the **last** chunk always runs inline on the caller's
    /// thread, so a dispatch enqueues at most `chunks - 1` jobs and
    /// `threads <= 1` (or a single row) touches no queue at all.
    ///
    /// `f` must compute rows identically regardless of which chunk (or
    /// worker) they land in; every caller in this crate guarantees that
    /// by delegating to (or matching) its scalar `*_reference` twin,
    /// which is what keeps parallel kernels bitwise deterministic across
    /// thread counts.
    pub fn par_rows<T, F>(&self, out: &mut [T], row_width: usize, threads: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        if out.is_empty() {
            return;
        }
        assert!(row_width > 0, "par_rows row_width must be positive");
        assert_eq!(out.len() % row_width, 0, "par_rows out not whole rows");
        let rows = out.len() / row_width;
        let t = threads.max(1).min(rows);
        if t == 1 {
            f(0, out);
            return;
        }
        // Register this dispatch's demand and size the pool to the
        // aggregate of every in-flight dispatch (the guard releases the
        // claim on return *or* unwind).
        let want = t - 1;
        let total = self.inflight.fetch_add(want, Ordering::Relaxed) + want;
        let _inflight = InflightGuard(&self.inflight, want);
        self.ensure_workers(total);
        // Span-path inheritance: queued jobs adopt the dispatcher's
        // current trace path so per-stage spans recorded inside kernels
        // nest under the caller (e.g. `forward.layer.ball_attention`)
        // regardless of which worker runs the chunk. Owned String, so
        // the lifetime erasure below stays sound; None when tracing is
        // off or the caller has no open span (zero cost either way).
        let parent = if crate::trace::spans_enabled() {
            crate::trace::current_path()
        } else {
            None
        };
        let chunks = chunk_rows(rows, t);
        let last = chunks.len() - 1;
        let latch = Latch::new(last);
        let mut rest = out;
        let mut inline_chunk: Option<(usize, &mut [T])> = None;
        for (ci, range) in chunks.iter().enumerate() {
            let take = (range.end - range.start) * row_width;
            let (chunk, tail) = {
                let r = std::mem::take(&mut rest);
                r.split_at_mut(take)
            };
            rest = tail;
            if ci == last {
                inline_chunk = Some((range.start, chunk));
            } else {
                let fr = &f;
                let latch_ref = &latch;
                let row0 = range.start;
                let job_parent = parent.clone();
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let _adopt = job_parent.map(crate::trace::adopt_parent);
                    let r = std::panic::catch_unwind(AssertUnwindSafe(|| fr(row0, chunk)));
                    latch_ref.complete(r.err());
                });
                // SAFETY: the job borrows `f`, `latch`, and a disjoint
                // sub-slice of `out`, all of which outlive `latch.wait`
                // below — and `wait` does not return until every job has
                // run `complete` as its final action. The inline chunk's
                // panic is caught so even an unwinding caller reaches the
                // wait. Erasing the lifetime is therefore sound for the
                // same reason `std::thread::scope` is.
                let job: Job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job)
                };
                self.push_job(job);
            }
        }
        let (row0, chunk) = inline_chunk.expect("chunks is never empty here");
        let inline_result = std::panic::catch_unwind(AssertUnwindSafe(|| f(row0, chunk)));
        latch.wait(self);
        if let Err(p) = inline_result {
            std::panic::resume_unwind(p);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self
                .shared
                .state
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            st.shutdown = true;
        }
        self.shared.work_ready.notify_all();
        let handles = std::mem::take(
            self.handles
                .get_mut()
                .unwrap_or_else(|poisoned| poisoned.into_inner()),
        );
        for h in handles {
            let _ = h.join();
        }
    }
}

/// The process-wide pool behind the free [`par_rows`]: created lazily on
/// first dispatch, grown on demand up to [`MAX_THREADS`] workers, and
/// shared by every kernel/backend in the process. It is intentionally
/// never torn down — the OS reclaims it at process exit; explicit
/// [`WorkerPool`]s join on drop.
pub fn global_pool() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    static GAUGES: OnceLock<()> = OnceLock::new();
    let pool = POOL.get_or_init(|| WorkerPool::new(0));
    // Register the saturation gauges exactly once. The callbacks capture
    // the &'static pool and are evaluated lazily at BSST/`bsa stats`
    // snapshot time — registration itself never reads pool state.
    GAUGES.get_or_init(|| {
        let p: &'static WorkerPool = POOL.get().expect("pool initialized above");
        crate::trace::register_gauge("pool.queue_depth", Box::new(move || p.queue_depth() as f64));
        crate::trace::register_gauge(
            "pool.live_workers",
            Box::new(move || p.live_workers() as f64),
        );
        crate::trace::register_gauge(
            "pool.busy_workers",
            Box::new(move || p.busy_workers() as f64),
        );
        crate::trace::register_gauge(
            "pool.inflight_demand",
            Box::new(move || p.inflight_demand() as f64),
        );
        crate::trace::register_gauge(
            "pool.utilization",
            Box::new(move || {
                let live = p.live_workers();
                if live == 0 {
                    0.0
                } else {
                    p.busy_workers() as f64 / live as f64
                }
            }),
        );
    });
    pool
}

/// Dispatch on the [`global_pool`] — the entry point every kernel in
/// [`super::linalg`]/[`super::kernels`] uses. See
/// [`WorkerPool::par_rows`] for the contract.
pub fn par_rows<T, F>(out: &mut [T], row_width: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    global_pool().par_rows(out, row_width, threads, f)
}

/// The pre-pool dispatcher: scoped threads spawned per call, joined
/// before return. Chunking and semantics are identical to [`par_rows`]
/// (same [`chunk_rows`], last chunk inline), so the two are bitwise
/// interchangeable — retained as the differential oracle for the pool
/// and as the comparator in the `pool_dispatch` spawn-overhead
/// microbench (`BENCH_native.json`). Production code paths should use
/// [`par_rows`].
pub fn par_rows_scoped<T, F>(out: &mut [T], row_width: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if out.is_empty() {
        return;
    }
    assert!(row_width > 0, "par_rows row_width must be positive");
    assert_eq!(out.len() % row_width, 0, "par_rows out not whole rows");
    let rows = out.len() / row_width;
    let t = threads.max(1).min(rows);
    if t == 1 {
        f(0, out);
        return;
    }
    let chunks = chunk_rows(rows, t);
    let last = chunks.len() - 1;
    let parent = if crate::trace::spans_enabled() {
        crate::trace::current_path()
    } else {
        None
    };
    std::thread::scope(|s| {
        let mut rest = out;
        for (ci, range) in chunks.iter().enumerate() {
            let take = range.end - range.start;
            let (chunk, tail) = {
                let r = std::mem::take(&mut rest);
                r.split_at_mut(take * row_width)
            };
            rest = tail;
            if ci == last {
                f(range.start, chunk);
            } else {
                let fr = &f;
                let row0 = range.start;
                let job_parent = parent.clone();
                s.spawn(move || {
                    let _adopt = job_parent.map(crate::trace::adopt_parent);
                    fr(row0, chunk)
                });
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn resolve_explicit_wins_and_is_capped() {
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(10_000), MAX_THREADS);
        assert!(resolve_threads(0) >= 1);
    }

    #[test]
    fn chunk_rows_partitions_in_order() {
        for rows in [0usize, 1, 5, 7, 16, 33] {
            for t in [1usize, 2, 3, 8, 64] {
                let chunks = chunk_rows(rows, t);
                let mut next = 0;
                for r in &chunks {
                    assert_eq!(r.start, next, "contiguous");
                    assert!(r.end > r.start, "non-empty");
                    next = r.end;
                }
                assert_eq!(next, rows, "covers 0..{rows}");
                assert!(chunks.len() <= t.max(1));
            }
        }
    }

    #[test]
    fn par_rows_touches_every_row_once() {
        for threads in [1usize, 2, 3, 7] {
            let rows = 23;
            let width = 4;
            let mut out = vec![0.0f32; rows * width];
            let calls = AtomicUsize::new(0);
            par_rows(&mut out, width, threads, |row0, chunk| {
                calls.fetch_add(1, Ordering::Relaxed);
                for (i, row) in chunk.chunks_exact_mut(width).enumerate() {
                    for v in row.iter_mut() {
                        *v += (row0 + i) as f32 + 1.0;
                    }
                }
            });
            for (i, row) in out.chunks_exact(width).enumerate() {
                for &v in row {
                    assert_eq!(v, i as f32 + 1.0, "row {i} threads {threads}");
                }
            }
            assert!(calls.load(Ordering::Relaxed) <= threads);
        }
    }

    #[test]
    fn par_rows_handles_empty_and_single_row() {
        let mut empty: Vec<f32> = vec![];
        par_rows(&mut empty, 8, 4, |_, _| panic!("must not be called"));
        let mut one = vec![0.0f32; 6];
        par_rows(&mut one, 6, 8, |row0, chunk| {
            assert_eq!(row0, 0);
            chunk.fill(1.0);
        });
        assert!(one.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn par_rows_works_for_usize_rows() {
        // topk writes index rows; par_rows is generic over Send elements
        let mut out = vec![0usize; 12];
        par_rows(&mut out, 3, 4, |row0, chunk| {
            for (i, row) in chunk.chunks_exact_mut(3).enumerate() {
                row.fill(row0 + i);
            }
        });
        for (i, row) in out.chunks_exact(3).enumerate() {
            assert!(row.iter().all(|&v| v == i));
        }
    }

    #[test]
    fn pool_reuses_workers_across_dispatches() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.worker_count(), 3);
        for round in 0..50 {
            let mut out = vec![0.0f32; 16 * 4];
            pool.par_rows(&mut out, 4, 3, |row0, chunk| {
                for (i, row) in chunk.chunks_exact_mut(4).enumerate() {
                    row.fill((row0 + i) as f32);
                }
            });
            for (i, row) in out.chunks_exact(4).enumerate() {
                assert!(row.iter().all(|&v| v == i as f32), "round {round} row {i}");
            }
            assert_eq!(pool.worker_count(), 3, "round {round} spawned extra workers");
        }
    }

    #[test]
    fn pool_grows_on_demand_and_caps() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.worker_count(), 0);
        let mut out = vec![0.0f32; 8];
        pool.par_rows(&mut out, 1, 4, |_, chunk| chunk.fill(1.0));
        // 4-way dispatch needs at most 3 workers (last chunk is inline)
        assert!(pool.worker_count() <= 3 && pool.worker_count() >= 1);
        assert!(out.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn drop_joins_all_workers() {
        let pool = WorkerPool::new(4);
        let gauge = pool.live_gauge();
        let mut out = vec![0.0f32; 32];
        pool.par_rows(&mut out, 2, 4, |_, chunk| chunk.fill(2.0));
        assert_eq!(gauge.load(Ordering::SeqCst), 4);
        drop(pool);
        assert_eq!(gauge.load(Ordering::SeqCst), 0, "drop must join every worker");
    }

    #[test]
    fn nested_par_rows_completes() {
        // A job that itself dispatches must not deadlock: the waiter
        // helps by running queued jobs (the head-parallel attention path
        // nests kernel dispatches exactly like this).
        let mut out = vec![0.0f32; 8 * 32];
        par_rows(&mut out, 32, 4, |row0, chunk| {
            for (i, row) in chunk.chunks_exact_mut(32).enumerate() {
                let r = row0 + i;
                par_rows(row, 8, 3, |sub0, sub| {
                    for (j, cell) in sub.iter_mut().enumerate() {
                        *cell = (r * 100 + sub0 * 8 + j) as f32;
                    }
                });
            }
        });
        for (e, &v) in out.iter().enumerate() {
            let (r, within) = (e / 32, e % 32);
            assert_eq!(v, (r * 100 + within) as f32, "elem {e}");
        }
    }

    #[test]
    fn par_rows_propagates_job_panics() {
        // Panic in a queued job (first chunk) must surface on the
        // caller — and the pool must stay usable afterwards.
        let result = std::panic::catch_unwind(|| {
            let mut out = vec![0.0f32; 16];
            par_rows(&mut out, 2, 4, |row0, _chunk| {
                if row0 == 0 {
                    panic!("job boom");
                }
            });
        });
        assert!(result.is_err(), "job panic must propagate");
        let mut out = vec![0.0f32; 16];
        par_rows(&mut out, 2, 4, |_, chunk| chunk.fill(3.0));
        assert!(out.iter().all(|&v| v == 3.0), "pool unusable after panic");
    }

    #[test]
    fn pool_matches_scoped_dispatcher_bitwise() {
        let src: Vec<f32> = (0..96).map(|i| (i as f32).sin()).collect();
        let work = |row0: usize, chunk: &mut [f32]| {
            for (i, row) in chunk.chunks_exact_mut(8).enumerate() {
                let s = &src[(row0 + i) * 8..(row0 + i + 1) * 8];
                let mut acc = 0.0f32;
                for &x in s {
                    acc += x * x;
                }
                for v in row.iter_mut() {
                    *v = acc;
                }
            }
        };
        for threads in [1usize, 2, 3, 5] {
            let mut a = vec![0.0f32; 96];
            let mut b = vec![0.0f32; 96];
            par_rows(&mut a, 8, threads, work);
            par_rows_scoped(&mut b, 8, threads, work);
            assert_eq!(a, b, "pool vs scoped at threads={threads}");
        }
    }
}
