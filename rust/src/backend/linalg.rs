//! Dense f32 primitives for the native backend: GEMM, stable softmax,
//! RMSNorm, activations — cache-blocked and thread-parallel.
//!
//! All functions operate on flat row-major slices with explicit
//! dimensions (no `Tensor` overhead on the per-head hot loops). Each
//! performance kernel has a `*_reference` scalar twin — the original
//! single-threaded loop-nest. Work is split into contiguous row chunks
//! dispatched on the persistent worker pool (see
//! [`super::pool::par_rows`]) and blocking/packing never reorders any
//! output element's floating-point accumulation — which worker runs a
//! chunk, or how often the pool is reused, cannot change a bit, so
//! outputs are **bitwise stable across thread counts**.
//!
//! The inner loops run on the [`super::simd`] microkernels. Kernels
//! built only from element-parallel panels ([`matmul`] via
//! `simd::axpy`) stay **bitwise equal** to their twins at every SIMD
//! level; kernels built on horizontal reductions ([`matmul_nt`] via
//! `simd::dot`, [`softmax_rows`] via the max/exp-sum panels,
//! [`rms_norm`] via `simd::sum_sq`) match their twins to the **1e-5**
//! differential bound when SIMD is active and bitwise when it is off
//! (`BSA_NATIVE_SIMD=off`). The differential harness in
//! `rust/tests/conformance.rs` sweeps randomized shapes and thread
//! counts against the twins; see the "Kernel conformance" section of
//! [`super`]'s docs before touching either side of a pair. (The
//! attention hot path in [`super::kernels`] now streams its softmax
//! tile-by-tile and no longer materializes score rows through
//! [`softmax_rows`]; the full-row softmax here serves the materialized
//! comparator and any dense-row callers.)
//!
//! The GEMM is a panel-blocked kernel: B is packed one `KC x NC` panel
//! at a time into a dense per-thread buffer (so the inner loops stream a
//! hot, contiguous panel instead of striding through all of B), and each
//! thread owns a contiguous block of output rows. Panels are visited in
//! ascending-k order, so every `out[i][j]` still accumulates its k terms
//! in exactly the reference order.

use super::{pool, simd};

/// k-dimension panel height for the packed GEMM.
const KC: usize = 256;
/// n-dimension panel width for the packed GEMM.
const NC: usize = 128;
/// Register-blocking factor (output rows sharing one streamed B row) for
/// the transposed GEMM.
const MR: usize = 4;
/// RMSNorm epsilon, matching the jax reference (`model.rms_norm`,
/// eps 1e-6) — shared by the SIMD path, the scalar twin, and the
/// backward pass ([`super::grad`]) so the three can never drift apart.
pub const RMS_EPS: f32 = 1e-6;

/// `out = a @ b` where `a` is `(m, k)`, `b` is `(k, n)`, `out` is
/// `(m, n)`. Panel-blocked and parallel over output-row chunks;
/// bitwise equal to [`matmul_reference`] for all shapes and `threads`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "matmul a len");
    assert_eq!(b.len(), k * n, "matmul b len");
    assert_eq!(out.len(), m * n, "matmul out len");
    if m == 0 || n == 0 {
        return;
    }
    let lvl = simd::active();
    pool::par_rows(out, n, threads, |row0, orows| {
        let rows = orows.len() / n;
        matmul_rows_blocked(lvl, &a[row0 * k..(row0 + rows) * k], b, rows, k, n, orows);
    });
}

/// Serial panel kernel for one contiguous block of output rows. Packs B
/// `KC x NC` panels; per output element the k terms are accumulated in
/// ascending order, exactly like the scalar reference (the
/// [`simd::axpy`] inner panel is element-parallel, so it is bitwise
/// identical to the scalar loop at every SIMD level). When all of B
/// already fits in a single panel (`k <= KC && n <= NC` — every
/// per-head kernel matmul at the paper widths) packing would copy B
/// once to read it once, so the i-k-j nest streams B directly instead:
/// no packed buffer, no allocation, identical accumulation order.
fn matmul_rows_blocked(
    lvl: simd::Level,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    out.fill(0.0);
    if k <= KC && n <= NC {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                simd::axpy_at(lvl, av, &b[kk * n..(kk + 1) * n], orow);
            }
        }
        return;
    }
    let mut packed = vec![0.0f32; KC.min(k.max(1)) * NC.min(n)];
    let mut jc = 0;
    while jc < n {
        let ncb = NC.min(n - jc);
        let mut kc = 0;
        while kc < k {
            let kcb = KC.min(k - kc);
            for kk in 0..kcb {
                let src = (kc + kk) * n + jc;
                packed[kk * ncb..(kk + 1) * ncb].copy_from_slice(&b[src..src + ncb]);
            }
            for i in 0..m {
                let arow = &a[i * k + kc..i * k + kc + kcb];
                let orow = &mut out[i * n + jc..i * n + jc + ncb];
                for (kk, &av) in arow.iter().enumerate() {
                    simd::axpy_at(lvl, av, &packed[kk * ncb..(kk + 1) * ncb], orow);
                }
            }
            kc += kcb;
        }
        jc += ncb;
    }
}

/// Scalar twin of [`matmul`]: the classic i-k-j loop nest, single
/// thread, no blocking. The conformance oracle.
pub fn matmul_reference(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "matmul a len");
    assert_eq!(b.len(), k * n, "matmul b len");
    assert_eq!(out.len(), m * n, "matmul out len");
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// Scalar dot product — the reference twins' accumulation order
/// (identical to [`simd::dot_scalar`]).
#[inline]
fn dot(x: &[f32], y: &[f32]) -> f32 {
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// `out = a @ b^T` where `a` is `(m, k)`, `b` is `(n, k)`, `out` is
/// `(m, n)` — the attention-score shape. Register-blocked (each loaded B
/// row is reused across `MR` output rows) and parallel over output-row
/// chunks. The per-element [`simd::dot`] reduction makes this a 1e-5
/// twin of [`matmul_nt_reference`] when SIMD is active (bitwise when
/// off, and always bitwise across thread counts).
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "matmul_nt a len");
    assert_eq!(b.len(), n * k, "matmul_nt b len");
    assert_eq!(out.len(), m * n, "matmul_nt out len");
    if m == 0 || n == 0 {
        return;
    }
    let lvl = simd::active();
    pool::par_rows(out, n, threads, |row0, orows| {
        let rows = orows.len() / n;
        let a = &a[row0 * k..(row0 + rows) * k];
        let mut i = 0;
        while i < rows {
            let mb = MR.min(rows - i);
            for j in 0..n {
                let brow = &b[j * k..(j + 1) * k];
                for ii in 0..mb {
                    orows[(i + ii) * n + j] =
                        simd::dot_at(lvl, &a[(i + ii) * k..(i + ii + 1) * k], brow);
                }
            }
            i += mb;
        }
    });
}

/// Scalar twin of [`matmul_nt`]: row-by-row dot products.
pub fn matmul_nt_reference(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "matmul_nt a len");
    assert_eq!(b.len(), n * k, "matmul_nt b len");
    assert_eq!(out.len(), m * n, "matmul_nt out len");
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// In-place row-wise softmax over a `(rows, cols)` matrix, parallel
/// over row chunks (rows are independent). With SIMD active each row
/// runs the [`simd::row_max`] / [`simd::exp_sum`] / [`simd::scale`]
/// panels (polynomial exp, lane-tree sum, reciprocal-multiply
/// normalize) — a 1e-5 twin of [`softmax_rows_reference`]; with SIMD
/// off each chunk runs the scalar twin verbatim, bitwise.
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize, threads: usize) {
    assert_eq!(x.len(), rows * cols, "softmax len");
    let lvl = simd::active();
    if lvl == simd::Level::Scalar {
        pool::par_rows(x, cols, threads, |_, chunk| {
            softmax_rows_reference(chunk, chunk.len() / cols, cols);
        });
        return;
    }
    pool::par_rows(x, cols, threads, |_, chunk| {
        for row in chunk.chunks_exact_mut(cols) {
            softmax_row_simd(lvl, row);
        }
    });
}

/// One softmax row on the SIMD panels at a pre-resolved level. (The
/// attention kernels in [`super::kernels`] no longer share this — the
/// streaming path folds the softmax into its online tile loop; this is
/// now only the materialized path's row body.)
#[inline]
fn softmax_row_simd(lvl: simd::Level, row: &mut [f32]) {
    let max = simd::row_max_at(lvl, row);
    let sum = simd::exp_sum_at(lvl, row, max);
    // All-(-inf) rows cannot occur here (the own-ball mask uses a large
    // finite value), but guard the normalization anyway.
    if sum > 0.0 {
        simd::scale_at(lvl, row, 1.0 / sum);
    }
}

/// Scalar twin of [`softmax_rows`]: row-wise max-subtracted softmax.
pub fn softmax_rows_reference(x: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols, "softmax len");
    for row in x.chunks_exact_mut(cols) {
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        // All-(-inf) rows cannot occur here (the own-ball mask uses a
        // large finite value), but guard the division anyway.
        if sum > 0.0 {
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
    }
}

/// Row-wise RMSNorm (Zhang & Sennrich 2019): `out = x / rms(x) * scale`
/// with `rms = sqrt(mean(x^2) + eps)`, matching the jax reference
/// (`model.rms_norm`, eps 1e-6). Parallel over row chunks. The
/// mean-square reduction runs on [`simd::sum_sq`] when SIMD is active
/// (1e-5 twin of [`rms_norm_reference`]; bitwise when off and across
/// thread counts — the per-element normalization is identical either
/// way).
pub fn rms_norm(x: &[f32], scale: &[f32], rows: usize, cols: usize, threads: usize, out: &mut [f32]) {
    assert_eq!(x.len(), rows * cols, "rms_norm x len");
    assert_eq!(scale.len(), cols, "rms_norm scale len");
    assert_eq!(out.len(), rows * cols, "rms_norm out len");
    let lvl = simd::active();
    if lvl == simd::Level::Scalar {
        pool::par_rows(out, cols, threads, |row0, ochunk| {
            let r = ochunk.len() / cols;
            rms_norm_reference(&x[row0 * cols..(row0 + r) * cols], scale, r, cols, ochunk);
        });
        return;
    }
    pool::par_rows(out, cols, threads, |row0, ochunk| {
        let r = ochunk.len() / cols;
        let xr = &x[row0 * cols..(row0 + r) * cols];
        for (xrow, orow) in xr.chunks_exact(cols).zip(ochunk.chunks_exact_mut(cols)) {
            let ms = simd::sum_sq_at(lvl, xrow) / cols as f32;
            let inv = 1.0 / (ms + RMS_EPS).sqrt();
            for ((o, &v), &s) in orow.iter_mut().zip(xrow).zip(scale) {
                *o = v * inv * s;
            }
        }
    });
}

/// Scalar twin of [`rms_norm`].
pub fn rms_norm_reference(x: &[f32], scale: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    assert_eq!(x.len(), rows * cols, "rms_norm x len");
    assert_eq!(scale.len(), cols, "rms_norm scale len");
    assert_eq!(out.len(), rows * cols, "rms_norm out len");
    for (xr, or) in x.chunks_exact(cols).zip(out.chunks_exact_mut(cols)) {
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / cols as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        for ((o, &v), &s) in or.iter_mut().zip(xr).zip(scale) {
            *o = v * inv * s;
        }
    }
}

/// Add a length-`cols` bias to every row of a `(rows, cols)` matrix
/// (memory-bound; stays serial).
pub fn add_bias(x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols, "add_bias x len");
    assert_eq!(bias.len(), cols, "add_bias bias len");
    for row in x.chunks_exact_mut(cols) {
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Logistic sigmoid.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// SiLU / swish activation: `x * sigmoid(x)`.
#[inline]
pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn matmul_small_known() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = [1., 2., 3., 4.];
        let b = [5., 6., 7., 8.];
        let mut out = [0.0f32; 4];
        matmul(&a, &b, 2, 2, 2, 1, &mut out);
        assert_eq!(out, [19., 22., 43., 50.]);
        let mut refr = [0.0f32; 4];
        matmul_reference(&a, &b, 2, 2, 2, &mut refr);
        assert_eq!(out, refr);
    }

    #[test]
    fn matmul_blocked_crosses_panel_boundaries_bitwise() {
        // k > KC and n > NC so the panel loops actually iterate
        let (m, k, n) = (5usize, KC + 7, NC + 33);
        let a = Rng::new(1).normals(m * k);
        let b = Rng::new(2).normals(k * n);
        for threads in [1usize, 2, 3] {
            let mut fast = vec![0.0f32; m * n];
            matmul(&a, &b, m, k, n, threads, &mut fast);
            let mut refr = vec![0.0f32; m * n];
            matmul_reference(&a, &b, m, k, n, &mut refr);
            assert_eq!(fast, refr, "threads {threads}");
        }
    }

    #[test]
    fn matmul_nt_matches_matmul_of_transpose() {
        let a = [1., 2., 3., 4., 5., 6.]; // (2, 3)
        let b = [1., 0., 1., 2., 1., 0., 0., 1., 1., 1., 1., 1.]; // (4, 3)
        let mut bt = vec![0.0f32; 12]; // (3, 4)
        for i in 0..4 {
            for j in 0..3 {
                bt[j * 4 + i] = b[i * 3 + j];
            }
        }
        let mut x = vec![0.0f32; 8];
        let mut y = vec![0.0f32; 8];
        matmul_nt(&a, &b, 2, 3, 4, 2, &mut x);
        matmul(&a, &bt, 2, 3, 4, 1, &mut y);
        assert_eq!(x, y);
        let mut refr = vec![0.0f32; 8];
        matmul_nt_reference(&a, &b, 2, 3, 4, &mut refr);
        assert_eq!(x, refr);
    }

    #[test]
    fn matmul_handles_degenerate_dims() {
        // m = 0 and n = 0 are no-ops, k = 0 zeroes the output
        let mut empty: Vec<f32> = vec![];
        matmul(&[], &[1.0, 2.0], 0, 1, 2, 4, &mut empty);
        matmul(&[1.0, 2.0], &[], 2, 1, 0, 4, &mut empty);
        matmul_nt(&[], &[1.0, 2.0], 0, 1, 2, 4, &mut empty);
        let mut out = vec![9.0f32; 4];
        matmul(&[], &[], 2, 0, 2, 4, &mut out);
        assert_eq!(out, [0.0; 4]);
    }

    #[test]
    fn softmax_rows_sum_to_one_and_order_preserved() {
        let mut x = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 2, 3, 2);
        for row in x.chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "row sums to {s}");
            assert!(row[0] < row[1] && row[1] < row[2]);
        }
    }

    #[test]
    fn softmax_stable_under_huge_logits() {
        let mut x = vec![1e30f32, 1e30, -1e30, 3e4, -3e4, 0.0];
        softmax_rows(&mut x, 2, 3, 1);
        assert!(x.iter().all(|v| v.is_finite()));
        let s0: f32 = x[..3].iter().sum();
        let s1: f32 = x[3..].iter().sum();
        assert!((s0 - 1.0).abs() < 1e-6 && (s1 - 1.0).abs() < 1e-6);
        assert!((x[0] - 0.5).abs() < 1e-6 && (x[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn rms_norm_unit_scale_normalizes() {
        let x = vec![3.0f32, 4.0];
        let mut out = vec![0.0f32; 2];
        rms_norm(&x, &[1.0, 1.0], 1, 2, 2, &mut out);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-5);
        assert!((out[1] - 4.0 / rms).abs() < 1e-5);
    }

    #[test]
    fn bias_and_activations() {
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0];
        add_bias(&mut x, &[10.0, 20.0], 2, 2);
        assert_eq!(x, [11.0, 22.0, 13.0, 24.0]);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(silu(0.0).abs() < 1e-7);
        assert!((silu(10.0) - 10.0).abs() < 1e-3); // saturates to identity
    }
}
