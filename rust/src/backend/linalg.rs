//! Dense f32 primitives for the native backend: GEMM, stable softmax,
//! RMSNorm, activations.
//!
//! All functions operate on flat row-major slices with explicit
//! dimensions (no `Tensor` overhead on the per-head hot loops) and are
//! allocation-free — callers own every buffer, matching the zero-copy
//! discipline of the serving batch assembler. The GEMM uses i-k-j loop
//! order so the inner loop streams both the output row and the B row
//! sequentially (the classic cache-friendly ordering for row-major
//! operands); at the model widths involved (<= a few hundred columns)
//! this is within a small factor of a blocked kernel and keeps the code
//! dependency-free.

/// `out = a @ b` where `a` is `(m, k)`, `b` is `(k, n)`, `out` is `(m, n)`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "matmul a len");
    assert_eq!(b.len(), k * n, "matmul b len");
    assert_eq!(out.len(), m * n, "matmul out len");
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `out = a @ b^T` where `a` is `(m, k)`, `b` is `(n, k)`, `out` is
/// `(m, n)` — the attention-score shape (queries against keys), where
/// both operands are row-major and the dot products run over contiguous
/// rows.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "matmul_nt a len");
    assert_eq!(b.len(), n * k, "matmul_nt b len");
    assert_eq!(out.len(), m * n, "matmul_nt out len");
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            *o = arow.iter().zip(brow).map(|(x, y)| x * y).sum();
        }
    }
}

/// In-place row-wise softmax over a `(rows, cols)` matrix, with the
/// standard max-subtraction so large-magnitude logits stay finite.
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols, "softmax len");
    for row in x.chunks_exact_mut(cols) {
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        // All-(-inf) rows cannot occur here (the own-ball mask uses a
        // large finite value), but guard the division anyway.
        if sum > 0.0 {
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
    }
}

/// Row-wise RMSNorm (Zhang & Sennrich 2019): `out = x / rms(x) * scale`
/// with `rms = sqrt(mean(x^2) + eps)`, matching the jax reference
/// (`model.rms_norm`, eps 1e-6).
pub fn rms_norm(x: &[f32], scale: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    assert_eq!(x.len(), rows * cols, "rms_norm x len");
    assert_eq!(scale.len(), cols, "rms_norm scale len");
    assert_eq!(out.len(), rows * cols, "rms_norm out len");
    const EPS: f32 = 1e-6;
    for (xr, or) in x.chunks_exact(cols).zip(out.chunks_exact_mut(cols)) {
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / cols as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        for ((o, &v), &s) in or.iter_mut().zip(xr).zip(scale) {
            *o = v * inv * s;
        }
    }
}

/// Add a length-`cols` bias to every row of a `(rows, cols)` matrix.
pub fn add_bias(x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols, "add_bias x len");
    assert_eq!(bias.len(), cols, "add_bias bias len");
    for row in x.chunks_exact_mut(cols) {
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Logistic sigmoid.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// SiLU / swish activation: `x * sigmoid(x)`.
#[inline]
pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = [1., 2., 3., 4.];
        let b = [5., 6., 7., 8.];
        let mut out = [0.0f32; 4];
        matmul(&a, &b, 2, 2, 2, &mut out);
        assert_eq!(out, [19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_nt_matches_matmul_of_transpose() {
        let a = [1., 2., 3., 4., 5., 6.]; // (2, 3)
        let b = [1., 0., 1., 2., 1., 0., 0., 1., 1., 1., 1., 1.]; // (4, 3)
        let mut bt = vec![0.0f32; 12]; // (3, 4)
        for i in 0..4 {
            for j in 0..3 {
                bt[j * 4 + i] = b[i * 3 + j];
            }
        }
        let mut x = vec![0.0f32; 8];
        let mut y = vec![0.0f32; 8];
        matmul_nt(&a, &b, 2, 3, 4, &mut x);
        matmul(&a, &bt, 2, 3, 4, &mut y);
        assert_eq!(x, y);
    }

    #[test]
    fn softmax_rows_sum_to_one_and_order_preserved() {
        let mut x = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 2, 3);
        for row in x.chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "row sums to {s}");
            assert!(row[0] < row[1] && row[1] < row[2]);
        }
    }

    #[test]
    fn softmax_stable_under_huge_logits() {
        let mut x = vec![1e30f32, 1e30, -1e30, 3e4, -3e4, 0.0];
        softmax_rows(&mut x, 2, 3);
        assert!(x.iter().all(|v| v.is_finite()));
        let s0: f32 = x[..3].iter().sum();
        let s1: f32 = x[3..].iter().sum();
        assert!((s0 - 1.0).abs() < 1e-6 && (s1 - 1.0).abs() < 1e-6);
        assert!((x[0] - 0.5).abs() < 1e-6 && (x[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn rms_norm_unit_scale_normalizes() {
        let x = vec![3.0f32, 4.0];
        let mut out = vec![0.0f32; 2];
        rms_norm(&x, &[1.0, 1.0], 1, 2, &mut out);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-5);
        assert!((out[1] - 4.0 / rms).abs() < 1e-5);
    }

    #[test]
    fn bias_and_activations() {
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0];
        add_bias(&mut x, &[10.0, 20.0], 2, 2);
        assert_eq!(x, [11.0, 22.0, 13.0, 24.0]);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(silu(0.0).abs() < 1e-7);
        assert!((silu(10.0) - 10.0).abs() < 1e-3); // saturates to identity
    }
}
