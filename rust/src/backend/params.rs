//! Native BSA parameters: named-array loading, shape validation, and
//! deterministic host-side initialization.
//!
//! Array names are the dotted pytree paths shared by the AOT manifest,
//! the trainer's checkpoints, and the `params_<tag>.bsackpt` files
//! aot.py emits next to the HLO artifacts (`blocks.0.attn.wq`,
//! `embed_w`, `norm_out`, ...). A full training checkpoint is accepted
//! too: its optimizer-moment arrays (`m.*`, `v.*`) are skipped.
//!
//! The `.bsackpt` container itself (magic, header, per-array layout,
//! bounds, and the error cases `rust/tests/conformance.rs` pins) is
//! specified in `docs/FORMATS.md`; the reader/writer lives in
//! [`checkpoint`](crate::coordinator::checkpoint).

use std::collections::BTreeMap;
use std::path::Path;

use crate::prng::Rng;
use crate::tensor::Tensor;

/// Projections of one BSA attention layer.
#[derive(Debug, Clone)]
pub struct AttnParams {
    pub wq: Tensor, // (C, C)
    pub wk: Tensor, // (C, C)
    pub wv: Tensor, // (C, C)
    pub wo: Tensor, // (C, C)
    /// Branch-gate projection, (C, 3H): sigmoid gates for the ball /
    /// compression / selection branches per token per head (eq. 9).
    pub wg: Tensor,
}

/// SwiGLU feed-forward weights.
#[derive(Debug, Clone)]
pub struct MlpParams {
    pub w1: Tensor, // (C, hidden)
    pub w2: Tensor, // (hidden, C)
    pub w3: Tensor, // (C, hidden)
}

/// One transformer block: RMSNorm -> BSA attention -> RMSNorm -> SwiGLU.
#[derive(Debug, Clone)]
pub struct BlockParams {
    pub attn: AttnParams,
    pub mlp: MlpParams,
    pub norm1: Tensor, // (C,)
    pub norm2: Tensor, // (C,)
}

/// Full parameter set of the BSA trunk (paper Sec. 3.1).
#[derive(Debug, Clone)]
pub struct NativeParams {
    pub embed_w: Tensor, // (in_features, C)
    pub embed_b: Tensor, // (C,)
    pub blocks: Vec<BlockParams>,
    pub norm_out: Tensor, // (C,)
    pub head_w: Tensor,   // (C, out_features)
    pub head_b: Tensor,   // (out_features,)
}

impl NativeParams {
    /// Assemble from named arrays (manifest / checkpoint / param-file
    /// naming). Optimizer-moment arrays (`m.*`, `v.*`) are ignored;
    /// unknown or missing model arrays are hard errors so a wrong file
    /// fails loudly instead of serving garbage.
    pub fn from_named(arrays: Vec<(String, Tensor)>) -> anyhow::Result<NativeParams> {
        let mut map: BTreeMap<String, Tensor> = BTreeMap::new();
        for (name, t) in arrays {
            if name.starts_with("m.") || name.starts_with("v.") {
                continue; // optimizer state in a full training checkpoint
            }
            anyhow::ensure!(
                map.insert(name.clone(), t).is_none(),
                "duplicate param array {name:?}"
            );
        }
        anyhow::ensure!(
            !map.keys().any(|k| k.contains(".attn.cmp.")),
            "param set uses MLP compression (cmp.*); the native backend \
             implements the paper-default mean-pooling phi only"
        );
        fn take(map: &mut BTreeMap<String, Tensor>, key: &str) -> anyhow::Result<Tensor> {
            map.remove(key)
                .ok_or_else(|| anyhow::anyhow!("param file missing array {key:?}"))
        }

        let mut blocks = Vec::new();
        loop {
            let i = blocks.len();
            if !map.contains_key(&format!("blocks.{i}.attn.wq")) {
                break;
            }
            blocks.push(BlockParams {
                attn: AttnParams {
                    wq: take(&mut map, &format!("blocks.{i}.attn.wq"))?,
                    wk: take(&mut map, &format!("blocks.{i}.attn.wk"))?,
                    wv: take(&mut map, &format!("blocks.{i}.attn.wv"))?,
                    wo: take(&mut map, &format!("blocks.{i}.attn.wo"))?,
                    wg: take(&mut map, &format!("blocks.{i}.attn.wg"))?,
                },
                mlp: MlpParams {
                    w1: take(&mut map, &format!("blocks.{i}.mlp.w1"))?,
                    w2: take(&mut map, &format!("blocks.{i}.mlp.w2"))?,
                    w3: take(&mut map, &format!("blocks.{i}.mlp.w3"))?,
                },
                norm1: take(&mut map, &format!("blocks.{i}.norm1"))?,
                norm2: take(&mut map, &format!("blocks.{i}.norm2"))?,
            });
        }
        anyhow::ensure!(!blocks.is_empty(), "param set has no blocks.*.attn.wq arrays \
             (is this a BSA model? full/erwin/pointnet params have no native backend)");
        let params = NativeParams {
            embed_w: take(&mut map, "embed_w")?,
            embed_b: take(&mut map, "embed_b")?,
            blocks,
            norm_out: take(&mut map, "norm_out")?,
            head_w: take(&mut map, "head_w")?,
            head_b: take(&mut map, "head_b")?,
        };
        anyhow::ensure!(
            map.is_empty(),
            "param file has unexpected arrays: {:?}",
            map.keys().take(6).collect::<Vec<_>>()
        );
        params.validate()?;
        Ok(params)
    }

    /// Load from a `.bsackpt` file (pure param file or full training
    /// checkpoint — see the module docs for the format).
    pub fn load(path: &Path) -> anyhow::Result<NativeParams> {
        let ck = crate::coordinator::checkpoint::Checkpoint::load(path)?;
        Self::from_named(ck.arrays)
            .map_err(|e| anyhow::anyhow!("loading native params from {}: {e}", path.display()))
    }

    /// Save as a `.bsackpt` param file with f32 storage (round-trips
    /// through [`load`](Self::load) exactly).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        self.save_with_dtype(path, crate::coordinator::checkpoint::Dtype::F32)
    }

    /// Save with an explicit storage dtype (the checkpoint v2 dtype
    /// axis). [`Dtype::F16`](crate::coordinator::checkpoint::Dtype)
    /// halves the file; each element is rounded to the nearest binary16
    /// value on write and up-converted exactly on load, so a reload
    /// returns the f16-grid quantization of these params — the same
    /// values `--precision f16` serving computes with.
    pub fn save_with_dtype(
        &self,
        path: &Path,
        dtype: crate::coordinator::checkpoint::Dtype,
    ) -> anyhow::Result<()> {
        let arrays = self
            .named_arrays()
            .into_iter()
            .map(|(n, t)| (n, t.clone()))
            .collect();
        crate::coordinator::checkpoint::Checkpoint { step: 0, arrays }.save_with_dtype(path, dtype)
    }

    /// Deterministic random initialization matching the jax init's
    /// *statistics* (Glorot-scaled normals for matrices, zeros for
    /// biases, ones for norms) — not its bit patterns; per-tensor PRNG
    /// streams keep the result independent of construction order.
    pub fn init(
        seed: u64,
        in_features: usize,
        out_features: usize,
        dim: usize,
        num_heads: usize,
        num_blocks: usize,
        mlp_ratio: usize,
    ) -> NativeParams {
        let base = Rng::new(seed ^ 0xB5A_BACE);
        let mut stream = 0u64;
        let mut linear = |fan_in: usize, fan_out: usize| -> Tensor {
            stream += 1;
            let mut rng = base.fold(stream);
            let s = (2.0 / (fan_in + fan_out) as f32).sqrt();
            let data = rng.normals(fan_in * fan_out).iter().map(|x| x * s).collect();
            Tensor::new(vec![fan_in, fan_out], data)
        };
        let hid = mlp_ratio * dim;
        let blocks = (0..num_blocks)
            .map(|_| BlockParams {
                attn: AttnParams {
                    wq: linear(dim, dim),
                    wk: linear(dim, dim),
                    wv: linear(dim, dim),
                    wo: linear(dim, dim),
                    wg: linear(dim, 3 * num_heads),
                },
                mlp: MlpParams {
                    w1: linear(dim, hid),
                    w2: linear(hid, dim),
                    w3: linear(dim, hid),
                },
                norm1: Tensor::full(vec![dim], 1.0),
                norm2: Tensor::full(vec![dim], 1.0),
            })
            .collect();
        NativeParams {
            embed_w: linear(in_features, dim),
            embed_b: Tensor::zeros(vec![dim]),
            blocks,
            norm_out: Tensor::full(vec![dim], 1.0),
            head_w: linear(dim, out_features),
            head_b: Tensor::zeros(vec![out_features]),
        }
    }

    /// Model width C (embedding columns).
    pub fn dim(&self) -> usize {
        self.embed_w.cols()
    }

    /// Attention heads, recovered from the gate projection `(C, 3H)`.
    pub fn num_heads(&self) -> usize {
        self.blocks[0].attn.wg.cols() / 3
    }

    /// Per-point input features (embedding rows).
    pub fn in_features(&self) -> usize {
        self.embed_w.shape()[0]
    }

    /// Per-point prediction features (head columns).
    pub fn out_features(&self) -> usize {
        self.head_w.cols()
    }

    /// Shape-consistency check across the whole trunk.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.embed_w.shape().len() == 2, "embed_w must be rank 2");
        let c = self.dim();
        anyhow::ensure!(c > 0 && !self.blocks.is_empty(), "empty model");
        anyhow::ensure!(self.embed_b.shape() == [c], "embed_b shape");
        anyhow::ensure!(self.norm_out.shape() == [c], "norm_out shape");
        anyhow::ensure!(self.head_w.shape() == [c, self.out_features()], "head_w shape");
        anyhow::ensure!(self.head_b.shape() == [self.out_features()], "head_b shape");
        let h = self.num_heads();
        anyhow::ensure!(h > 0 && c % h == 0, "dim {c} not divisible by heads {h}");
        for (i, b) in self.blocks.iter().enumerate() {
            for (nm, w) in [
                ("wq", &b.attn.wq),
                ("wk", &b.attn.wk),
                ("wv", &b.attn.wv),
                ("wo", &b.attn.wo),
            ] {
                anyhow::ensure!(w.shape() == [c, c], "blocks.{i}.attn.{nm} shape");
            }
            anyhow::ensure!(b.attn.wg.shape() == [c, 3 * h], "blocks.{i}.attn.wg shape");
            let hid = b.mlp.w1.cols();
            anyhow::ensure!(b.mlp.w1.shape() == [c, hid], "blocks.{i}.mlp.w1 shape");
            anyhow::ensure!(b.mlp.w2.shape() == [hid, c], "blocks.{i}.mlp.w2 shape");
            anyhow::ensure!(b.mlp.w3.shape() == [c, hid], "blocks.{i}.mlp.w3 shape");
            anyhow::ensure!(b.norm1.shape() == [c], "blocks.{i}.norm1 shape");
            anyhow::ensure!(b.norm2.shape() == [c], "blocks.{i}.norm2 shape");
        }
        Ok(())
    }

    /// `(name, tensor)` view in manifest naming, for saving and tests.
    pub fn named_arrays(&self) -> Vec<(String, &Tensor)> {
        let mut out: Vec<(String, &Tensor)> = Vec::new();
        for (i, b) in self.blocks.iter().enumerate() {
            out.push((format!("blocks.{i}.attn.wg"), &b.attn.wg));
            out.push((format!("blocks.{i}.attn.wk"), &b.attn.wk));
            out.push((format!("blocks.{i}.attn.wo"), &b.attn.wo));
            out.push((format!("blocks.{i}.attn.wq"), &b.attn.wq));
            out.push((format!("blocks.{i}.attn.wv"), &b.attn.wv));
            out.push((format!("blocks.{i}.mlp.w1"), &b.mlp.w1));
            out.push((format!("blocks.{i}.mlp.w2"), &b.mlp.w2));
            out.push((format!("blocks.{i}.mlp.w3"), &b.mlp.w3));
            out.push((format!("blocks.{i}.norm1"), &b.norm1));
            out.push((format!("blocks.{i}.norm2"), &b.norm2));
        }
        out.push(("embed_b".into(), &self.embed_b));
        out.push(("embed_w".into(), &self.embed_w));
        out.push(("head_b".into(), &self.head_b));
        out.push(("head_w".into(), &self.head_w));
        out.push(("norm_out".into(), &self.norm_out));
        out
    }

    /// Mutable `(name, tensor)` view — **same order as
    /// [`Self::named_arrays`]** (the Adam update and checkpoint
    /// restore zip the two, so order drift would silently mispair
    /// moments with parameters; `params::tests` pins the pairing).
    pub fn named_arrays_mut(&mut self) -> Vec<(String, &mut Tensor)> {
        let mut out: Vec<(String, &mut Tensor)> = Vec::new();
        for (i, b) in self.blocks.iter_mut().enumerate() {
            out.push((format!("blocks.{i}.attn.wg"), &mut b.attn.wg));
            out.push((format!("blocks.{i}.attn.wk"), &mut b.attn.wk));
            out.push((format!("blocks.{i}.attn.wo"), &mut b.attn.wo));
            out.push((format!("blocks.{i}.attn.wq"), &mut b.attn.wq));
            out.push((format!("blocks.{i}.attn.wv"), &mut b.attn.wv));
            out.push((format!("blocks.{i}.mlp.w1"), &mut b.mlp.w1));
            out.push((format!("blocks.{i}.mlp.w2"), &mut b.mlp.w2));
            out.push((format!("blocks.{i}.mlp.w3"), &mut b.mlp.w3));
            out.push((format!("blocks.{i}.norm1"), &mut b.norm1));
            out.push((format!("blocks.{i}.norm2"), &mut b.norm2));
        }
        out.push(("embed_b".into(), &mut self.embed_b));
        out.push(("embed_w".into(), &mut self.embed_w));
        out.push(("head_b".into(), &mut self.head_b));
        out.push(("head_w".into(), &mut self.head_w));
        out.push(("norm_out".into(), &mut self.norm_out));
        out
    }

    /// Zero-filled copy of this parameter tree — gradient and
    /// optimizer-moment buffers (`super::grad`) are shaped by cloning
    /// the model so they can never drift from it.
    pub fn zeros_like(&self) -> NativeParams {
        let zt = |t: &Tensor| Tensor::zeros(t.shape().to_vec());
        NativeParams {
            embed_w: zt(&self.embed_w),
            embed_b: zt(&self.embed_b),
            blocks: self
                .blocks
                .iter()
                .map(|b| BlockParams {
                    attn: AttnParams {
                        wq: zt(&b.attn.wq),
                        wk: zt(&b.attn.wk),
                        wv: zt(&b.attn.wv),
                        wo: zt(&b.attn.wo),
                        wg: zt(&b.attn.wg),
                    },
                    mlp: MlpParams {
                        w1: zt(&b.mlp.w1),
                        w2: zt(&b.mlp.w2),
                        w3: zt(&b.mlp.w3),
                    },
                    norm1: zt(&b.norm1),
                    norm2: zt(&b.norm2),
                })
                .collect(),
            norm_out: zt(&self.norm_out),
            head_w: zt(&self.head_w),
            head_b: zt(&self.head_b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> NativeParams {
        NativeParams::init(0, 6, 1, 32, 2, 2, 4)
    }

    #[test]
    fn init_shapes_and_derived_dims() {
        let p = tiny();
        p.validate().unwrap();
        assert_eq!(p.dim(), 32);
        assert_eq!(p.num_heads(), 2);
        assert_eq!(p.in_features(), 6);
        assert_eq!(p.out_features(), 1);
        assert_eq!(p.blocks.len(), 2);
        assert_eq!(p.blocks[0].mlp.w1.shape(), &[32, 128]);
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let a = tiny();
        let b = tiny();
        assert_eq!(a.embed_w, b.embed_w);
        assert_eq!(a.blocks[1].attn.wq, b.blocks[1].attn.wq);
        let c = NativeParams::init(1, 6, 1, 32, 2, 2, 4);
        assert_ne!(a.embed_w, c.embed_w);
    }

    #[test]
    fn named_roundtrip_through_from_named() {
        let p = tiny();
        let arrays: Vec<(String, Tensor)> = p
            .named_arrays()
            .into_iter()
            .map(|(n, t)| (n, t.clone()))
            .collect();
        let q = NativeParams::from_named(arrays).unwrap();
        assert_eq!(p.embed_w, q.embed_w);
        assert_eq!(p.blocks[0].attn.wg, q.blocks[0].attn.wg);
        assert_eq!(p.blocks[1].norm2, q.blocks[1].norm2);
    }

    #[test]
    fn from_named_skips_optimizer_moments() {
        let p = tiny();
        let mut arrays: Vec<(String, Tensor)> = p
            .named_arrays()
            .into_iter()
            .map(|(n, t)| (n, t.clone()))
            .collect();
        let moments: Vec<(String, Tensor)> = arrays
            .iter()
            .flat_map(|(n, t)| {
                [(format!("m.{n}"), t.clone()), (format!("v.{n}"), t.clone())]
            })
            .collect();
        arrays.extend(moments);
        let q = NativeParams::from_named(arrays).unwrap();
        assert_eq!(q.blocks.len(), 2);
    }

    #[test]
    fn from_named_rejects_missing_and_unknown() {
        let p = tiny();
        let arrays: Vec<(String, Tensor)> = p
            .named_arrays()
            .into_iter()
            .filter(|(n, _)| n != "head_w")
            .map(|(n, t)| (n, t.clone()))
            .collect();
        let err = NativeParams::from_named(arrays).unwrap_err().to_string();
        assert!(err.contains("head_w"), "{err}");

        let mut arrays: Vec<(String, Tensor)> = p
            .named_arrays()
            .into_iter()
            .map(|(n, t)| (n, t.clone()))
            .collect();
        arrays.push(("surprise".into(), Tensor::zeros(vec![1])));
        assert!(NativeParams::from_named(arrays).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let p = tiny();
        let path = std::env::temp_dir().join("bsa_native_params_test.bsackpt");
        p.save(&path).unwrap();
        let q = NativeParams::load(&path).unwrap();
        assert_eq!(p.embed_w, q.embed_w);
        assert_eq!(p.blocks[1].mlp.w2, q.blocks[1].mlp.w2);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn save_f16_loads_as_half_grid_quantization() {
        let p = tiny();
        let path = std::env::temp_dir().join("bsa_native_params_f16_test.bsackpt");
        p.save_with_dtype(&path, crate::coordinator::checkpoint::Dtype::F16)
            .unwrap();
        let q = NativeParams::load(&path).unwrap();
        q.validate().unwrap();
        let mut want = p.embed_w.data().to_vec();
        crate::half::quantize_slice(&mut want);
        assert_eq!(q.embed_w.data(), &want[..]);
        // Glorot-scaled init values sit well inside the f16 normal
        // range, so quantization error obeys the 2^-11 relative bound.
        for (a, b) in p.embed_w.data().iter().zip(q.embed_w.data()) {
            assert!((a - b).abs() <= a.abs() / 2048.0 + 1e-7, "{a} vs {b}");
        }
        std::fs::remove_file(path).ok();
    }
}
