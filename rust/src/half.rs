//! Software IEEE 754 binary16 ("half") conversion — no hardware f16
//! support or external crates required.
//!
//! The native backend uses f16 as a **storage** format only (checkpoint
//! arrays with the v2 dtype byte, activation staging buffers in
//! `backend::native` under `--precision f16`); every arithmetic kernel
//! still accumulates in f32. These routines are therefore the entire
//! f16 "ALU": encode f32 → u16 bits with round-to-nearest-even, decode
//! u16 bits → f32 exactly.
//!
//! Semantics (validated bit-for-bit against `numpy.float16` by
//! `python/tests/test_streaming_mirror.py`):
//!
//! * round-to-nearest-even on encode, including the subnormal range;
//! * overflow (|x| ≥ 65520 after rounding) encodes ±inf;
//! * underflow below half the smallest subnormal (≈ 2.98e-8) encodes ±0;
//! * NaN encodes to a quiet NaN that preserves the sign bit;
//! * decode is exact — every f16 value is representable in f32 — so
//!   `decode(encode(x))` is the nearest-even f16 rounding of `x`, with
//!   relative error ≤ 2⁻¹¹ for results in the normal range
//!   (the tolerance-tier bound documented in `backend`'s
//!   "Kernel conformance").

/// Encode one f32 as IEEE binary16 bits (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // inf / NaN: keep NaN-ness (set a mantissa bit so a signalling
        // payload never collapses to inf), keep the sign.
        return if mant == 0 { sign | 0x7c00 } else { sign | 0x7e00 };
    }

    // Unbiased exponent; f16 bias is 15, f32 bias is 127.
    let e = exp - 127 + 15;
    if e >= 0x1f {
        // Overflows the f16 exponent range: ±inf. (The largest finite
        // f16 is 65504; anything that would round beyond it lands here
        // via the rounding carry below or this branch directly.)
        return sign | 0x7c00;
    }
    if e <= 0 {
        // Subnormal (or zero) in f16. Shift the implicit-1 mantissa so
        // the result has no implicit bit, then round to nearest even.
        if e < -10 {
            return sign; // below half the smallest subnormal: ±0
        }
        let m = mant | 0x0080_0000; // implicit 1
        let shift = (14 - e) as u32; // 14..=24
        let half_ulp = 1u32 << (shift - 1);
        let mut half = m >> shift;
        let rem = m & ((1 << shift) - 1);
        if rem > half_ulp || (rem == half_ulp && (half & 1) == 1) {
            half += 1; // may carry into the smallest normal — still valid
        }
        return sign | half as u16;
    }

    // Normal range: keep the top 10 mantissa bits, round to nearest even.
    let mut half = ((e as u32) << 10) | (mant >> 13);
    let rem = mant & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1) {
        half += 1; // mantissa carry may bump the exponent; 0x7c00 == inf is correct
    }
    sign | half as u16
}

/// Decode IEEE binary16 bits to f32 (exact — f32 covers all of f16).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;

    let bits = if exp == 0 {
        if mant == 0 {
            sign // ±0
        } else {
            // Subnormal: value = mant * 2^-24. Normalize into f32.
            let shift = mant.leading_zeros() - 21; // bring MSB to bit 10
            let m = (mant << shift) & 0x03ff;
            let e = 127 - 15 - shift + 1;
            sign | (e << 23) | (m << 13)
        }
    } else if exp == 0x1f {
        if mant == 0 {
            sign | 0x7f80_0000 // ±inf
        } else {
            sign | 0x7fc0_0000 | (mant << 13) // NaN, payload preserved
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Encode a slice into a caller-owned bit buffer (resized to match).
pub fn encode_slice(src: &[f32], dst: &mut Vec<u16>) {
    dst.clear();
    dst.extend(src.iter().map(|&x| f32_to_f16_bits(x)));
}

/// Decode a bit slice into a caller-owned f32 buffer (resized to match).
pub fn decode_slice(src: &[u16], dst: &mut Vec<f32>) {
    dst.clear();
    dst.extend(src.iter().map(|&h| f16_bits_to_f32(h)));
}

/// Round every element to the nearest f16 value in place — the f32 view
/// of f16 storage. `backend::native` uses this to quantize parameters
/// once at load time under `--precision f16`, so the arithmetic sees
/// exactly the values a true half-precision store would hold.
pub fn quantize_slice(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = f16_bits_to_f32(f32_to_f16_bits(*v));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(x: f32) -> f32 {
        f16_bits_to_f32(f32_to_f16_bits(x))
    }

    #[test]
    fn known_encodings() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // largest finite
        assert_eq!(f32_to_f16_bits(5.960_464_5e-8), 0x0001); // smallest subnormal
        assert_eq!(f32_to_f16_bits(6.103_515_6e-5), 0x0400); // smallest normal
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn overflow_saturates_to_infinity() {
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00); // rounds past 65504
        assert_eq!(f32_to_f16_bits(1e30), 0x7c00);
        assert_eq!(f32_to_f16_bits(-1e30), 0xfc00);
        // 65519.996 rounds down to 65504, not inf
        assert_eq!(f32_to_f16_bits(65519.0), 0x7bff);
    }

    #[test]
    fn underflow_flushes_to_signed_zero() {
        assert_eq!(f32_to_f16_bits(1e-10), 0x0000);
        assert_eq!(f32_to_f16_bits(-1e-10), 0x8000);
        // exactly half the smallest subnormal ties to even (zero)
        assert_eq!(f32_to_f16_bits(2.980_232_2e-8), 0x0000);
    }

    #[test]
    fn round_to_nearest_even_on_ties() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16
        // (1 + 2^-10); nearest-even keeps the even mantissa (1.0).
        assert_eq!(f32_to_f16_bits(1.0 + 0.000_488_281_25), 0x3c00);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9; nearest-even
        // rounds up to the even mantissa 0x3c02.
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 0.000_488_281_25), 0x3c02);
    }

    #[test]
    fn decode_covers_every_bit_pattern() {
        // Exhaustive: decode all 65536 patterns, re-encode the finite
        // ones; the round-trip must be the identity (f16 -> f32 is exact
        // and the nearest f16 to an exact f16 value is itself).
        for h in 0..=u16::MAX {
            let x = f16_bits_to_f32(h);
            if x.is_nan() {
                assert!(f16_bits_to_f32(f32_to_f16_bits(x)).is_nan());
                continue;
            }
            let back = f32_to_f16_bits(x);
            assert_eq!(back, h, "pattern {h:#06x} decoded to {x} re-encoded to {back:#06x}");
        }
    }

    #[test]
    fn relative_error_bound_holds_for_normals() {
        // |roundtrip(x) - x| <= 2^-11 * |x| for x in the f16 normal range
        let mut rng = crate::prng::Rng::new(7);
        for u in rng.normals(10_000) {
            let x = u * 100.0;
            if x.abs() < 6.2e-5 || x.abs() > 65000.0 {
                continue;
            }
            let r = roundtrip(x);
            assert!(
                (r - x).abs() <= x.abs() * (1.0 / 2048.0),
                "x={x} roundtrip={r}"
            );
        }
    }

    #[test]
    fn slice_helpers_roundtrip() {
        let src = vec![0.5f32, -1.25, 3.75e-5, 1e30, -0.0];
        let mut bits = Vec::new();
        encode_slice(&src, &mut bits);
        let mut back = Vec::new();
        decode_slice(&bits, &mut back);
        assert_eq!(back.len(), src.len());
        assert_eq!(back[0], 0.5);
        assert_eq!(back[1], -1.25);
        assert_eq!(back[3], f32::INFINITY);
        assert_eq!(back[4].to_bits(), (-0.0f32).to_bits());
        let mut q = src.clone();
        quantize_slice(&mut q);
        assert_eq!(q, back);
    }
}
