//! Tiny visualization helpers: PPM images and 3D→2D point projections,
//! used by `examples/receptive_field.rs` to render Figure 2.

use std::path::Path;

/// RGB raster image written as binary PPM (P6) — viewable everywhere,
/// zero dependencies.
pub struct Image {
    pub width: usize,
    pub height: usize,
    data: Vec<u8>, // RGB8
}

impl Image {
    pub fn new(width: usize, height: usize) -> Image {
        Image { width, height, data: vec![24; width * height * 3] }
    }

    pub fn put(&mut self, x: usize, y: usize, rgb: [u8; 3]) {
        if x < self.width && y < self.height {
            let i = (y * self.width + x) * 3;
            self.data[i..i + 3].copy_from_slice(&rgb);
        }
    }

    /// Filled disc (for point splatting).
    pub fn splat(&mut self, x: f32, y: f32, r: i32, rgb: [u8; 3]) {
        let xi = x.round() as i32;
        let yi = y.round() as i32;
        for dy in -r..=r {
            for dx in -r..=r {
                if dx * dx + dy * dy <= r * r {
                    let (px, py) = (xi + dx, yi + dy);
                    if px >= 0 && py >= 0 {
                        self.put(px as usize, py as usize, rgb);
                    }
                }
            }
        }
    }

    pub fn save_ppm(&self, path: &Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        write!(f, "P6\n{} {}\n255\n", self.width, self.height)?;
        f.write_all(&self.data)
    }
}

/// Map a scalar in [0, 1] to a blue→white→red diverging colormap.
pub fn diverging(t: f32) -> [u8; 3] {
    let t = t.clamp(0.0, 1.0);
    if t < 0.5 {
        let u = t * 2.0;
        [(60.0 + 195.0 * u) as u8, (80.0 + 175.0 * u) as u8, 255]
    } else {
        let u = (t - 0.5) * 2.0;
        [255, (255.0 - 175.0 * u) as u8, (255.0 - 195.0 * u) as u8]
    }
}

/// Orthographic projection of (x, y, z) points onto the image plane,
/// auto-scaled to fit. Returns pixel coordinates per point.
pub fn project_xz(coords: &crate::tensor::Tensor, w: usize, h: usize) -> Vec<(f32, f32)> {
    let n = coords.rows();
    let (mut x0, mut x1) = (f32::INFINITY, f32::NEG_INFINITY);
    let (mut z0, mut z1) = (f32::INFINITY, f32::NEG_INFINITY);
    for i in 0..n {
        let r = coords.row(i);
        x0 = x0.min(r[0]);
        x1 = x1.max(r[0]);
        let z = *r.last().unwrap();
        z0 = z0.min(z);
        z1 = z1.max(z);
    }
    let sx = (w as f32 - 20.0) / (x1 - x0).max(1e-6);
    let sz = (h as f32 - 20.0) / (z1 - z0).max(1e-6);
    let s = sx.min(sz);
    (0..n)
        .map(|i| {
            let r = coords.row(i);
            let z = *r.last().unwrap();
            (10.0 + (r[0] - x0) * s, h as f32 - 10.0 - (z - z0) * s)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn image_roundtrip_header() {
        let mut img = Image::new(8, 4);
        img.put(0, 0, [255, 0, 0]);
        img.splat(4.0, 2.0, 1, [0, 255, 0]);
        let path = std::env::temp_dir().join("bsa_viz_test.ppm");
        img.save_ppm(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P6\n8 4\n255\n"));
        assert_eq!(bytes.len(), 11 + 8 * 4 * 3);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn colormap_endpoints() {
        assert_eq!(diverging(0.0)[2], 255); // blue end
        assert_eq!(diverging(1.0)[0], 255); // red end
    }

    #[test]
    fn projection_fits_canvas() {
        let pts = Tensor::new(vec![3, 3], vec![-1., 0., -1., 0., 0., 0., 1., 0., 1.]);
        let px = project_xz(&pts, 100, 100);
        for (x, y) in px {
            assert!((0.0..100.0).contains(&x));
            assert!((0.0..100.0).contains(&y));
        }
    }

    #[test]
    fn out_of_bounds_put_ignored() {
        let mut img = Image::new(4, 4);
        img.put(100, 100, [1, 2, 3]); // must not panic
    }
}
