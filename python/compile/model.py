"""L2: the BSA transformer and baselines, as pure-jax functions.

Model zoo (all exposed through ``forward(name, params, x, cfg)``):

  * ``bsa``       — the paper's model: N blocks of RMSNorm -> BSA -> SwiGLU
                    (Sec. 3.1); variants via BSAConfig.group_select /
                    group_compress (Table 3 rows).
  * ``full``      — Full Attention baseline (Vaswani 2017), same trunk with
                    the attention swapped for dense flash attention.
  * ``erwin``     — Erwin-style hierarchical baseline (Zhdanov 2025): BTA
                    U-Net with mean-pool coarsening and skip connections.
  * ``pointnet``  — PointNet segmentation-style baseline (Qi 2016).

Every attention primitive has two implementations selected by
``cfg.kernels``: the Pallas kernel (interpret=True) or the pure-jnp oracle
from kernels/ref.py. The Pallas forward passes are wrapped in
``jax.custom_vjp`` with the oracle's VJP as the backward rule — the pytest
suite proves kernel == oracle to f32 tolerance, so gradients are exact
while keeping the kernel on the forward hot path.

This file is build-time only: aot.py lowers ``init`` / ``forward`` /
``train_step`` to HLO text and the rust runtime never imports Python.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .params import BSAConfig, TrainConfig
from .kernels import ref
from .kernels.ball_attention import ball_attention as _ball_pallas
from .kernels.flash_attention import flash_attention as _flash_pallas
from .kernels.compress import compress_mean as _cmean_pallas
from .kernels.compress import compress_mlp as _cmlp_pallas
from .kernels.select_attention import select_attention as _select_pallas


# ---------------------------------------------------------------------------
# custom_vjp wrappers: Pallas forward, oracle backward
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def ball_attention_p(q, k, v, ball_size):
    return _ball_pallas(q, k, v, ball_size)


def _ball_fwd(q, k, v, ball_size):
    return ball_attention_p(q, k, v, ball_size), (q, k, v)


def _ball_bwd(ball_size, res, ct):
    q, k, v = res
    _, vjp = jax.vjp(lambda a, b, c: ref.ref_ball_attention(a, b, c, ball_size), q, k, v)
    return vjp(ct)


ball_attention_p.defvjp(_ball_fwd, _ball_bwd)


@jax.custom_vjp
def flash_attention_p(q, k, v):
    return _flash_pallas(q, k, v)


def _flash_fwd(q, k, v):
    return flash_attention_p(q, k, v), (q, k, v)


def _flash_bwd(res, ct):
    q, k, v = res
    _, vjp = jax.vjp(ref.softmax_attention, q, k, v)
    return vjp(ct)


flash_attention_p.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def compress_mean_p(x, block):
    return _cmean_pallas(x, block)


def _cmean_fwd(x, block):
    return compress_mean_p(x, block), (x,)


def _cmean_bwd(block, res, ct):
    (x,) = res
    _, vjp = jax.vjp(lambda a: ref.ref_compress_mean(a, block), x)
    return vjp(ct)


compress_mean_p.defvjp(_cmean_fwd, _cmean_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def compress_mlp_p(x, block, w1, b1, w2, b2):
    return _cmlp_pallas(x, block, w1, b1, w2, b2)


def _cmlp_fwd(x, block, w1, b1, w2, b2):
    return compress_mlp_p(x, block, w1, b1, w2, b2), (x, w1, b1, w2, b2)


def _cmlp_bwd(block, res, ct):
    x, w1, b1, w2, b2 = res
    _, vjp = jax.vjp(
        lambda a, c1, d1, c2, d2: ref.ref_compress_mlp(a, block, c1, d1, c2, d2),
        x, w1, b1, w2, b2,
    )
    return vjp(ct)


compress_mlp_p.defvjp(_cmlp_fwd, _cmlp_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def select_attention_p(q, k, v, idx, sel_block, group):
    return _select_pallas(q, k, v, idx, sel_block, group)


def _select_fwd(q, k, v, idx, sel_block, group):
    return select_attention_p(q, k, v, idx, sel_block, group), (q, k, v, idx)


def _select_bwd(sel_block, group, res, ct):
    q, k, v, idx = res
    _, vjp = jax.vjp(
        lambda a, b, c: ref.ref_select_attention(a, b, c, idx, sel_block, group),
        q, k, v,
    )
    dq, dk, dv = vjp(ct)
    d_idx = jnp.zeros(idx.shape, dtype=jax.dtypes.float0)
    return dq, dk, dv, d_idx


select_attention_p.defvjp(_select_fwd, _select_bwd)


# ---------------------------------------------------------------------------
# kernel dispatch (cfg.kernels: "pallas" | "ref")
# ---------------------------------------------------------------------------

def k_ball(cfg, q, k, v):
    if cfg.kernels == "pallas":
        return ball_attention_p(q, k, v, cfg.ball_size)
    return ref.ref_ball_attention(q, k, v, cfg.ball_size)


def k_dense(cfg, q, k, v):
    if cfg.kernels == "pallas":
        return flash_attention_p(q, k, v)
    return ref.softmax_attention(q, k, v)


def k_cmean(cfg, x, block):
    if cfg.kernels == "pallas":
        return compress_mean_p(x, block)
    return ref.ref_compress_mean(x, block)


def k_cmlp(cfg, x, block, w1, b1, w2, b2):
    if cfg.kernels == "pallas":
        return compress_mlp_p(x, block, w1, b1, w2, b2)
    return ref.ref_compress_mlp(x, block, w1, b1, w2, b2)


def k_select(cfg, q, k, v, idx, sel_block, group):
    if cfg.kernels == "pallas":
        return select_attention_p(q, k, v, idx, sel_block, group)
    return ref.ref_select_attention(q, k, v, idx, sel_block, group)


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    """RMSNorm (Zhang & Sennrich 2019)."""
    rms = jnp.sqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return x / rms * scale


def swiglu(params, x):
    """SwiGLU feed-forward (Shazeer 2020)."""
    h = jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])
    return h @ params["w2"]


def _split_heads(x, num_heads):
    """(B, N, C) -> (B*H, N, C/H)."""
    b, n, c = x.shape
    dh = c // num_heads
    x = x.reshape(b, n, num_heads, dh).transpose(0, 2, 1, 3)
    return x.reshape(b * num_heads, n, dh)


def _merge_heads(x, batch, num_heads):
    """(B*H, N, dh) -> (B, N, C)."""
    s, n, dh = x.shape
    x = x.reshape(batch, num_heads, n, dh).transpose(0, 2, 1, 3)
    return x.reshape(batch, n, num_heads * dh)


# ---------------------------------------------------------------------------
# BSA attention layer (paper Sec. 2.2)
# ---------------------------------------------------------------------------

def bsa_attention(params, x, cfg: BSAConfig):
    """Three-branch Ball Sparse Attention on (B, N, C) -> (B, N, C)."""
    b, n, c = x.shape
    h = cfg.num_heads

    q = _split_heads(x @ params["wq"], h)  # (S, N, dh)
    k = _split_heads(x @ params["wk"], h)
    v = _split_heads(x @ params["wv"], h)

    # ---- compression branch (eq. 5): coarse KV
    if cfg.mlp_compress:
        cp = params["cmp"]
        kc = k_cmlp(cfg, k, cfg.cmp_block, cp["w1"], cp["b1"], cp["w2"], cp["b2"])
        vc = k_cmlp(cfg, v, cfg.cmp_block, cp["w1"], cp["b1"], cp["w2"], cp["b2"])
    else:
        kc = k_cmean(cfg, k, cfg.cmp_block)
        vc = k_cmean(cfg, v, cfg.cmp_block)

    if cfg.group_compress:
        # eq. 15: pooled queries, output repeated l times
        if cfg.mlp_compress:
            cp = params["cmp"]
            qc = k_cmlp(cfg, q, cfg.cmp_block, cp["w1"], cp["b1"], cp["w2"], cp["b2"])
        else:
            qc = k_cmean(cfg, q, cfg.cmp_block)
        o_cmp = jnp.repeat(k_dense(cfg, qc, kc, vc), cfg.cmp_block, axis=1)
    else:
        o_cmp = k_dense(cfg, q, kc, vc)

    # ---- selection branch (eqs. 6-8, 10-12)
    g = cfg.group_size if cfg.group_select else 1
    # group-mean queries (linearity => equals averaging per-token scores)
    qg = q.reshape(b * h, n // g, g, -1).mean(axis=2) if g > 1 else q
    scores = jnp.einsum("sgd,sbd->sgb", qg, kc)
    if cfg.mask_own_ball:
        scores = ref.ref_ball_mask(scores, g, cfg.cmp_block, cfg.ball_size)
    idx = ref.ref_topk_indices(scores, cfg.top_k)
    idx = jax.lax.stop_gradient(idx)
    o_slc = k_select(cfg, q, k, v, idx, cfg.cmp_block, g)

    # ---- ball branch (eq. 3)
    o_ball = k_ball(cfg, q, k, v)

    # ---- gated fusion (eq. 9): per-token per-head sigmoid gates
    gates = jax.nn.sigmoid(x @ params["wg"])          # (B, N, 3H)
    gates = gates.reshape(b, n, 3, h).transpose(2, 0, 3, 1)  # (3, B, H, N)
    gates = gates.reshape(3, b * h, n, 1)
    out = gates[0] * o_ball + gates[1] * o_cmp + gates[2] * o_slc

    return _merge_heads(out, b, h) @ params["wo"]


def full_attention(params, x, cfg: BSAConfig):
    """Dense baseline: same projections, flash attention over all pairs."""
    b, n, c = x.shape
    h = cfg.num_heads
    q = _split_heads(x @ params["wq"], h)
    k = _split_heads(x @ params["wk"], h)
    v = _split_heads(x @ params["wv"], h)
    out = k_dense(cfg, q, k, v)
    return _merge_heads(out, b, h) @ params["wo"]


def bta_attention(params, x, cfg: BSAConfig, ball_size=None):
    """Ball-Tree-Attention-only layer (Erwin's local attention)."""
    b, n, c = x.shape
    h = cfg.num_heads
    m = min(ball_size or cfg.ball_size, n)
    q = _split_heads(x @ params["wq"], h)
    k = _split_heads(x @ params["wk"], h)
    v = _split_heads(x @ params["wv"], h)
    out = k_ball(_with_ball(cfg, m), q, k, v)
    return _merge_heads(out, b, h) @ params["wo"]


def _with_ball(cfg: BSAConfig, m: int) -> BSAConfig:
    import dataclasses

    return dataclasses.replace(cfg, ball_size=m)


# ---------------------------------------------------------------------------
# transformer trunk
# ---------------------------------------------------------------------------

def _block_forward(params, x, cfg, attn_fn):
    x = x + attn_fn(params["attn"], rms_norm(x, params["norm1"]), cfg)
    x = x + swiglu(params["mlp"], rms_norm(x, params["norm2"]))
    return x


def _trunk_forward(params, x, cfg, attn_fn):
    x = x @ params["embed_w"] + params["embed_b"]
    for blk in params["blocks"]:
        x = _block_forward(blk, x, cfg, attn_fn)
    x = rms_norm(x, params["norm_out"])
    return x @ params["head_w"] + params["head_b"]


def bsa_forward(params, x, cfg: BSAConfig):
    """The paper's model: (B, N, in_features) -> (B, N, out_features)."""
    cfg.validate(x.shape[1])
    return _trunk_forward(params, x, cfg, bsa_attention)


def full_forward(params, x, cfg: BSAConfig):
    return _trunk_forward(params, x, cfg, full_attention)


# ---------------------------------------------------------------------------
# Erwin-style hierarchical baseline
# ---------------------------------------------------------------------------

ERWIN_POOL = 4          # coarsening factor between levels
ERWIN_LEVELS = 2        # encoder levels before the bottleneck
ERWIN_BALL = 128        # leaf-level ball size


def erwin_forward(params, x, cfg: BSAConfig):
    """BTA U-Net: local attention, coarsen, bottleneck, refine with skips.

    Captures Erwin's inductive bias (hierarchical locality, progressive
    pooling) with mean-pool coarsening; fidelity loss at coarse levels is
    exactly the property BSA's global branches are designed to avoid.
    """
    b, n, _ = x.shape
    x = x @ params["embed_w"] + params["embed_b"]

    skips = []
    for lvl in range(ERWIN_LEVELS):
        blk = params["enc"][lvl]
        m = min(ERWIN_BALL, x.shape[1])
        x = x + bta_attention(blk["attn"], rms_norm(x, blk["norm1"]), cfg, m)
        x = x + swiglu(blk["mlp"], rms_norm(x, blk["norm2"]))
        skips.append(x)
        bb, nn, cc = x.shape
        x = x.reshape(bb, nn // ERWIN_POOL, ERWIN_POOL, cc).mean(axis=2)

    blk = params["mid"]
    m = min(ERWIN_BALL, x.shape[1])
    x = x + bta_attention(blk["attn"], rms_norm(x, blk["norm1"]), cfg, m)
    x = x + swiglu(blk["mlp"], rms_norm(x, blk["norm2"]))

    for lvl in reversed(range(ERWIN_LEVELS)):
        x = jnp.repeat(x, ERWIN_POOL, axis=1) + skips[lvl]
        blk = params["dec"][lvl]
        m = min(ERWIN_BALL, x.shape[1])
        x = x + bta_attention(blk["attn"], rms_norm(x, blk["norm1"]), cfg, m)
        x = x + swiglu(blk["mlp"], rms_norm(x, blk["norm2"]))

    x = rms_norm(x, params["norm_out"])
    return x @ params["head_w"] + params["head_b"]


# ---------------------------------------------------------------------------
# PointNet baseline
# ---------------------------------------------------------------------------

def pointnet_forward(params, x, cfg: BSAConfig):
    """Per-point MLP -> global max-pool -> concat -> per-point MLP head."""
    h = x
    for w, bb in params["local"]:
        h = jax.nn.relu(h @ w + bb)
    g = jnp.max(h, axis=1, keepdims=True)                     # (B, 1, C)
    h = jnp.concatenate([h, jnp.broadcast_to(g, h.shape)], axis=-1)
    for i, (w, bb) in enumerate(params["head"]):
        h = h @ w + bb
        if i + 1 < len(params["head"]):
            h = jax.nn.relu(h)
    return h


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _linear_init(key, fan_in, fan_out):
    return jax.random.normal(key, (fan_in, fan_out)) * (2.0 / (fan_in + fan_out)) ** 0.5


def _attn_init(key, cfg: BSAConfig, with_cmp_mlp: bool, gated: bool = True):
    """Attention projections. ``gated=False`` (full/erwin layers) skips the
    branch-gate projection: XLA dead-code-eliminates unused entry params at
    lowering, which would desynchronize the artifact manifest."""
    ks = jax.random.split(key, 8)
    c = cfg.dim
    p = {
        "wq": _linear_init(ks[0], c, c),
        "wk": _linear_init(ks[1], c, c),
        "wv": _linear_init(ks[2], c, c),
        "wo": _linear_init(ks[3], c, c),
    }
    if gated:
        p["wg"] = _linear_init(ks[4], c, 3 * cfg.num_heads)
    if with_cmp_mlp:
        dh = cfg.head_dim
        hidden = 2 * dh
        p["cmp"] = {
            "w1": _linear_init(ks[5], cfg.cmp_block * dh, hidden),
            "b1": jnp.zeros((hidden,)),
            "w2": _linear_init(ks[6], hidden, dh),
            "b2": jnp.zeros((dh,)),
        }
    return p


def _mlp_init(key, cfg: BSAConfig):
    ks = jax.random.split(key, 3)
    c, hid = cfg.dim, cfg.mlp_ratio * cfg.dim
    return {
        "w1": _linear_init(ks[0], c, hid),
        "w2": _linear_init(ks[1], hid, c),
        "w3": _linear_init(ks[2], c, hid),
    }


def _block_init(key, cfg, with_cmp_mlp, gated=True):
    k1, k2 = jax.random.split(key)
    return {
        "attn": _attn_init(k1, cfg, with_cmp_mlp, gated),
        "mlp": _mlp_init(k2, cfg),
        "norm1": jnp.ones((cfg.dim,)),
        "norm2": jnp.ones((cfg.dim,)),
    }


def _trunk_init(key, cfg: BSAConfig, with_cmp_mlp=False, gated=True):
    ks = jax.random.split(key, cfg.num_blocks + 3)
    return {
        "embed_w": _linear_init(ks[0], cfg.in_features, cfg.dim),
        "embed_b": jnp.zeros((cfg.dim,)),
        "blocks": [
            _block_init(ks[1 + i], cfg, with_cmp_mlp, gated)
            for i in range(cfg.num_blocks)
        ],
        "norm_out": jnp.ones((cfg.dim,)),
        "head_w": _linear_init(ks[-2], cfg.dim, cfg.out_features),
        "head_b": jnp.zeros((cfg.out_features,)),
    }


def bsa_init(key, cfg: BSAConfig):
    return _trunk_init(key, cfg, with_cmp_mlp=cfg.mlp_compress)


def full_init(key, cfg: BSAConfig):
    return _trunk_init(key, cfg, gated=False)


def erwin_init(key, cfg: BSAConfig):
    ks = jax.random.split(key, 2 * ERWIN_LEVELS + 4)
    return {
        "embed_w": _linear_init(ks[0], cfg.in_features, cfg.dim),
        "embed_b": jnp.zeros((cfg.dim,)),
        "enc": [
            _block_init(ks[1 + i], cfg, False, gated=False) for i in range(ERWIN_LEVELS)
        ],
        "mid": _block_init(ks[1 + ERWIN_LEVELS], cfg, False, gated=False),
        "dec": [
            _block_init(ks[2 + ERWIN_LEVELS + i], cfg, False, gated=False)
            for i in range(ERWIN_LEVELS)
        ],
        "norm_out": jnp.ones((cfg.dim,)),
        "head_w": _linear_init(ks[-2], cfg.dim, cfg.out_features),
        "head_b": jnp.zeros((cfg.out_features,)),
    }


def pointnet_init(key, cfg: BSAConfig):
    widths = [cfg.in_features, 64, 128, cfg.dim * 2]
    ks = jax.random.split(key, len(widths) + 3)
    local = [
        (_linear_init(ks[i], widths[i], widths[i + 1]), jnp.zeros((widths[i + 1],)))
        for i in range(len(widths) - 1)
    ]
    cin = widths[-1] * 2
    head = [
        (_linear_init(ks[-3], cin, cfg.dim), jnp.zeros((cfg.dim,))),
        (_linear_init(ks[-2], cfg.dim, cfg.out_features), jnp.zeros((cfg.out_features,))),
    ]
    return {"local": local, "head": head}


MODELS = {
    "bsa": (bsa_init, bsa_forward),
    "full": (full_init, full_forward),
    "erwin": (erwin_init, erwin_forward),
    "pointnet": (pointnet_init, pointnet_forward),
}


def init(name, seed, cfg: BSAConfig):
    """Initialize params from an int32 seed scalar (traceable)."""
    key = jax.random.PRNGKey(seed)
    return MODELS[name][0](key, cfg)


def forward(name, params, x, cfg: BSAConfig):
    return MODELS[name][1](params, x, cfg)


# ---------------------------------------------------------------------------
# training (paper Appendix A): MSE loss + AdamW, schedule computed host-side
# ---------------------------------------------------------------------------

def loss_fn(name, params, x, y, cfg: BSAConfig):
    pred = forward(name, params, x, cfg)
    return jnp.mean(jnp.square(pred - y))


def adamw_update(params, grads, m, v, step, lr, tc: TrainConfig):
    """One AdamW step (Loshchilov & Hutter 2019). Decay on >=2-D leaves."""

    def upd(p, g, m_, v_):
        m_n = tc.beta1 * m_ + (1 - tc.beta1) * g
        v_n = tc.beta2 * v_ + (1 - tc.beta2) * jnp.square(g)
        m_hat = m_n / (1 - tc.beta1 ** step)
        v_hat = v_n / (1 - tc.beta2 ** step)
        delta = m_hat / (jnp.sqrt(v_hat) + tc.eps)
        wd = tc.weight_decay if p.ndim >= 2 else 0.0
        p_n = p - lr * (delta + wd * p)
        return p_n, m_n, v_n

    flat_p, tree = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tree, [o[2] for o in out])
    return new_p, new_m, new_v


def train_step(name, params, m, v, step, lr, x, y, cfg: BSAConfig, tc: TrainConfig):
    """One fused fwd+bwd+AdamW step.

    ``step`` (1-based, f32) and ``lr`` are runtime scalars fed by the rust
    coordinator each call, keeping the lowered graph schedule-free.
    Returns (new_params, new_m, new_v, loss).
    """
    loss, grads = jax.value_and_grad(lambda p: loss_fn(name, p, x, y, cfg))(params)
    new_p, new_m, new_v = adamw_update(params, grads, m, v, step, lr, tc)
    return new_p, new_m, new_v, loss


# ---------------------------------------------------------------------------
# standalone attention layers for the runtime-scaling figures (F3/F4)
# ---------------------------------------------------------------------------

ATTN_LAYERS = {
    "bsa": bsa_attention,
    "full": full_attention,
    "bta": lambda p, x, cfg: bta_attention(p, x, cfg, cfg.ball_size),
}


def attn_layer_init(key, cfg: BSAConfig, kind: str = "bsa"):
    """Params for a standalone layer; only BSA kinds carry branch gates."""
    return _attn_init(
        key, cfg, with_cmp_mlp=cfg.mlp_compress, gated=kind.startswith("bsa")
    )


def attn_layer_forward(kind, params, x, cfg: BSAConfig):
    """Single attention layer (B, N, C) -> (B, N, C) for scaling benches."""
    return ATTN_LAYERS[kind](params, x, cfg)
