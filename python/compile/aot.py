"""AOT compiler: lower the model zoo to HLO text artifacts for rust.

Emits, per (model, task, N, B) combination in the selected suite:

  * ``init_<tag>.hlo.txt``   — (seed:i32) -> flat params
  * ``fwd_<tag>.hlo.txt``    — (params..., x) -> prediction
  * ``train_<tag>.hlo.txt``  — (params..., m..., v..., step, lr, x, y)
                               -> (params..., m..., v..., loss)
  * ``attn_<kind>_n<N>.hlo.txt`` — single attention layer for the
                               runtime-scaling figures (F3/F4)

plus ``manifest.txt`` describing every graph's I/O so the rust runtime
(rust/src/runtime/manifest.rs) can wire buffers without importing Python.

Interchange format is **HLO text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once at build time (``make artifacts``); Python never executes on the
rust request path.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .params import BSAConfig, TrainConfig

TC = TrainConfig()


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# artifact specs
# ---------------------------------------------------------------------------

# task -> per-point input features (must match rust/src/data generators)
TASK_FEATURES = {"air": 6, "ela": 4, "syn": 6}

# model variants (Table 3 rows)
VARIANTS = {
    "bsa": {},
    "bsa_nogs": {"group_select": False},
    "bsa_gc": {"group_compress": True, "mlp_compress": True},
    # design-choice ablations (DESIGN.md: own-ball mask, MLP phi)
    "bsa_nomask": {"mask_own_ball": False},
    "bsa_mlpcmp": {"mlp_compress": True},
    "full": {},
    "erwin": {},
    "pointnet": {},
}


def base_model(variant: str) -> str:
    return variant if variant in ("full", "erwin", "pointnet") else "bsa"


@dataclasses.dataclass(frozen=True)
class Spec:
    variant: str        # key into VARIANTS
    task: str           # key into TASK_FEATURES
    n: int
    batch: int
    dim: int = 64
    heads: int = 4
    blocks: int = 6
    ball: int = 256
    cmp_block: int = 8
    group: int = 8
    top_k: int = 4
    kernels: str = "pallas"
    train: bool = True  # also emit train_/init_ graphs (fwd always emitted)

    @property
    def tag(self) -> str:
        base = f"{self.variant}_{self.task}_n{self.n}_b{self.batch}"
        # ablation specs (Table 5) encode their block/group sizes
        if (self.cmp_block, self.group) != (8, 8):
            base += f"_l{self.cmp_block}g{self.group}"
        if self.kernels != "pallas":
            base += "_ref"
        return base

    def cfg(self) -> BSAConfig:
        kw = dict(VARIANTS[self.variant])
        return BSAConfig(
            dim=self.dim,
            num_heads=self.heads,
            num_blocks=self.blocks,
            in_features=TASK_FEATURES[self.task],
            ball_size=min(self.ball, self.n),
            cmp_block=self.cmp_block,
            group_size=self.group,
            top_k=self.top_k,
            kernels=self.kernels,
            **kw,
        )


def suite_specs(suite: str) -> list[Spec]:
    """Artifact sets. Keep `core` small: it gates every build."""
    core = [
        # e2e training driver + integration tests (airflow, paper arch @ small N)
        Spec("bsa", "air", 1024, 2),
        # serving path at the paper's ShapeNet scale
        Spec("bsa", "air", 4096, 1, train=False),
        # tiny graphs for fast cargo tests
        Spec("bsa", "syn", 256, 1, dim=32, heads=2, blocks=2, ball=64),
    ]
    # Training graphs for the accuracy tables are lowered with the
    # pure-jnp reference kernels: pytest proves kernel == ref numerics, and
    # ref lowers to XLA-fused HLO that trains ~3.7x faster on CPU than the
    # interpret-mode Pallas emulation (measured; see EXPERIMENTS.md §Perf).
    # The Pallas path stays on the inference/serving artifacts.
    table12 = [  # Tables 1-2: all trainable models on both tasks
        Spec(v, t, 1024, 2, kernels="ref")
        for t in ("air", "ela")
        for v in ("bsa", "full", "erwin", "pointnet")
    ]
    table3 = [  # Table 3: fwd-only at the paper's N=4096 for timing,
        # in both kernel modes (pallas = structure artifact, ref = XLA-fused
        # runtime measurement)
        Spec(v, "air", 4096, 1, train=False, kernels=k)
        for v in ("bsa", "bsa_nogs", "bsa_gc", "full", "erwin")
        for k in ("pallas", "ref")
    ] + [
        Spec("bsa_gc", "air", 1024, 2, kernels="ref"),
        Spec("bsa_nogs", "air", 1024, 2, kernels="ref"),
    ]
    table5 = [  # (l, g) ablation grid, trained short
        Spec("bsa", "air", 1024, 2, cmp_block=l, group=g, kernels="ref")
        for (l, g) in [(4, 4), (16, 16), (32, 32), (4, 8), (16, 8), (8, 4), (8, 16)]
    ]
    ablation = [  # design-choice ablations + batched-serving artifact
        Spec("bsa_nomask", "air", 1024, 2, kernels="ref"),
        Spec("bsa_mlpcmp", "air", 1024, 2, kernels="ref"),
        Spec("bsa", "air", 1024, 4, train=False, kernels="ref"),  # B=4 batching
    ]
    suites = {
        "core": core,
        "bench": table12 + table3 + table5 + ablation,
        "all": core + table12 + table3 + table5 + ablation,
    }
    return suites[suite]


SCALING_NS = [256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536]
SCALING_KINDS = ["bsa", "bsa_nogs", "bsa_gc", "full", "bta"]


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------

def _flat_names(params) -> list[str]:
    """Dotted path per flattened leaf, e.g. 'blocks.0.attn.wq'."""

    def key_part(k):
        if isinstance(k, jax.tree_util.DictKey):
            return str(k.key)
        if isinstance(k, jax.tree_util.SequenceKey):
            return str(k.idx)
        if isinstance(k, jax.tree_util.GetAttrKey):
            return str(k.name)
        return str(k)

    paths, _ = zip(*jax.tree_util.tree_flatten_with_path(params)[0])
    return [".".join(key_part(k) for k in p) for p in paths]


def _shape_str(x) -> str:
    dt = {"float32": "f32", "int32": "i32", "uint32": "u32"}[str(x.dtype)]
    dims = ",".join(str(d) for d in x.shape) if x.shape else "scalar"
    return f"{dt} {dims}"


class ManifestWriter:
    """Collects per-graph manifest sections.

    Merges with an existing manifest on write: a `--suite core` run must
    not clobber the entries a previous `--suite all --scaling` run wrote
    (stale entries whose .hlo.txt no longer exists are dropped).
    """

    def __init__(self):
        self.lines: list[str] = ["# bsa artifact manifest v1"]
        self.names: set[str] = set()

    def graph(self, name, fname, kind, tag, cfg: BSAConfig, n, batch, nparams,
              inputs, outputs, in_names=None, out_names=None):
        self.names.add(name)
        self.lines.append(f"[graph {name}]")
        self.lines.append(f"file {fname}")
        self.lines.append(f"kind {kind}")
        self.lines.append(f"tag {tag}")
        self.lines.append(f"n {n}")
        self.lines.append(f"batch {batch}")
        self.lines.append(f"nparams {nparams}")
        self.lines.append(f"ball_size {cfg.ball_size}")
        self.lines.append(f"cmp_block {cfg.cmp_block}")
        self.lines.append(f"group_size {cfg.group_size}")
        self.lines.append(f"top_k {cfg.top_k}")
        self.lines.append(f"in_features {cfg.in_features}")
        self.lines.append(f"out_features {cfg.out_features}")
        for i, x in enumerate(inputs):
            nm = in_names[i] if in_names else f"in{i}"
            self.lines.append(f"input {i} {_shape_str(x)} {nm}")
        for i, x in enumerate(outputs):
            nm = out_names[i] if out_names else f"out{i}"
            self.lines.append(f"output {i} {_shape_str(x)} {nm}")
        self.lines.append("")

    def write(self, path):
        out_dir = os.path.dirname(path)
        keep: list[str] = []
        if os.path.exists(path):
            block: list[str] = []
            keep_block = False

            def flush():
                if block and keep_block:
                    keep.extend(block + [""])

            for line in open(path).read().splitlines():
                line = line.rstrip()
                if line.startswith("[graph "):
                    flush()
                    name = line[len("[graph ") :].rstrip("]")
                    keep_block = name not in self.names
                    block = [line]
                    continue
                if not block:
                    continue
                if line.startswith("file ") and keep_block:
                    # drop entries whose artifact disappeared
                    if not os.path.exists(os.path.join(out_dir, line.split()[1])):
                        keep_block = False
                if line:
                    block.append(line)
            flush()
        with open(path, "w") as f:
            f.write("\n".join(self.lines) + "\n")
            if keep:
                f.write("\n".join(keep) + "\n")


def _emit(out_dir, fname, lower_thunk, force):
    """Lower + write unless the artifact already exists (lowering is the
    expensive step, so the cache check happens first)."""
    path = os.path.join(out_dir, fname)
    if os.path.exists(path) and not force:
        return False
    text = to_hlo_text(lower_thunk())
    with open(path, "w") as f:
        f.write(text)
    return True


def write_param_file(path, names, arrays, step=0):
    """Write named f32 arrays in the `.bsackpt` flat-binary container.

    Layout (little-endian, mirrors rust/src/coordinator/checkpoint.rs):
      magic "BSAC" | version u32 | step u64 | count u32
      per array: name_len u32 | name bytes | ndims u32 | dims u32... | f32 data

    This is the native rust backend's parameter interchange
    (rust/src/backend/params.rs): emitting it next to the HLO artifacts
    lets `bsa serve --backend native --params artifacts/params_<tag>.bsackpt`
    serve the exact weights the compiled init graph would produce.
    """
    import struct

    import numpy as np

    with open(path, "wb") as f:
        f.write(b"BSAC")
        f.write(struct.pack("<IQI", 1, step, len(arrays)))
        for name, arr in zip(names, arrays):
            a = np.asarray(arr, dtype="<f4")
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", a.ndim))
            for d in a.shape:
                f.write(struct.pack("<I", d))
            f.write(a.tobytes())


def lower_spec(spec: Spec, out_dir: str, mf: ManifestWriter, force: bool) -> None:
    cfg = spec.cfg()
    cfg.validate(spec.n)
    name = base_model(spec.variant)
    tag = spec.tag

    # abstract params for shape bookkeeping (no real init at build time)
    params = jax.eval_shape(lambda s: model.init(name, s, cfg), jnp.int32(0))
    flat, tree = jax.tree_util.tree_flatten(params)
    pnames = _flat_names(params)
    nparams = len(flat)

    x_spec = jax.ShapeDtypeStruct((spec.batch, spec.n, cfg.in_features), jnp.float32)
    y_spec = jax.ShapeDtypeStruct((spec.batch, spec.n, cfg.out_features), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    # ---- fwd: (params..., x) -> pred
    def fwd_flat(*args):
        p = jax.tree_util.tree_unflatten(tree, args[:nparams])
        return (model.forward(name, p, args[nparams], cfg),)

    fname = f"fwd_{tag}.hlo.txt"
    wrote = _emit(out_dir, fname, lambda: jax.jit(fwd_flat).lower(*flat, x_spec), force)
    mf.graph(
        f"fwd_{tag}", fname, "fwd", tag, cfg, spec.n, spec.batch, nparams,
        list(flat) + [x_spec], [y_spec],
        in_names=pnames + ["x"], out_names=["pred"],
    )
    print(f"  fwd_{tag}: {'wrote' if wrote else 'cached'}")

    # native-backend param file: concrete init(seed=0) weights alongside
    # the HLO so artifact-free rust hosts can still serve this tag's
    # exact initialization (BSA variants only — the native backend
    # implements the paper's bsa forward).
    if name == "bsa":
        pfile = os.path.join(out_dir, f"params_{tag}.bsackpt")
        if force or not os.path.exists(pfile):
            concrete = jax.jit(
                lambda s: tuple(jax.tree_util.tree_leaves(model.init(name, s, cfg)))
            )(jnp.int32(0))
            write_param_file(pfile, pnames, concrete)
            print(f"  params_{tag}.bsackpt: wrote")

    if not spec.train:
        return

    # ---- init: (seed) -> params...
    def init_flat(seed):
        return tuple(jax.tree_util.tree_leaves(model.init(name, seed, cfg)))

    fname = f"init_{tag}.hlo.txt"
    wrote = _emit(out_dir, fname, lambda: jax.jit(init_flat).lower(jax.ShapeDtypeStruct((), jnp.int32)), force)
    mf.graph(
        f"init_{tag}", fname, "init", tag, cfg, spec.n, spec.batch, nparams,
        [jax.ShapeDtypeStruct((), jnp.int32)], list(flat),
        in_names=["seed"], out_names=pnames,
    )
    print(f"  init_{tag}: {'wrote' if wrote else 'cached'}")

    # ---- train: (params..., m..., v..., step, lr, x, y) -> (p..., m..., v..., loss)
    def train_flat(*args):
        p = jax.tree_util.tree_unflatten(tree, args[:nparams])
        m = jax.tree_util.tree_unflatten(tree, args[nparams : 2 * nparams])
        v = jax.tree_util.tree_unflatten(tree, args[2 * nparams : 3 * nparams])
        step, lr, x, y = args[3 * nparams :]
        np_, nm, nv, loss = model.train_step(name, p, m, v, step, lr, x, y, cfg, TC)
        return tuple(
            jax.tree_util.tree_leaves(np_)
            + jax.tree_util.tree_leaves(nm)
            + jax.tree_util.tree_leaves(nv)
            + [loss]
        )

    train_in = list(flat) * 3 + [scalar, scalar, x_spec, y_spec]
    # donate params + optimizer state: enables in-place buffer reuse in PJRT
    donate = tuple(range(3 * nparams))
    fname = f"train_{tag}.hlo.txt"
    wrote = _emit(out_dir, fname, lambda: jax.jit(train_flat, donate_argnums=donate).lower(*train_in), force)
    state_names = pnames + [f"m.{s}" for s in pnames] + [f"v.{s}" for s in pnames]
    mf.graph(
        f"train_{tag}", fname, "train", tag, cfg, spec.n, spec.batch, nparams,
        train_in, list(flat) * 3 + [scalar],
        in_names=state_names + ["step", "lr", "x", "y"],
        out_names=state_names + ["loss"],
    )
    print(f"  train_{tag}: {'wrote' if wrote else 'cached'}")


def lower_attn(
    kind: str, n: int, out_dir: str, mf: ManifestWriter, force: bool, kernels: str = "pallas"
) -> None:
    """Single attention layer for the F3/F4 runtime-scaling benches.

    Emitted twice per (kind, n): with Pallas interpret kernels (the
    correctness/structure artifact) and with the pure-jnp reference
    (XLA-fused; the hardware-independent runtime measurement — interpret
    mode's while-loop emulation is not a TPU performance proxy).
    """
    kw = dict(VARIANTS.get(kind, {}))
    layer = "bsa" if kind.startswith("bsa") else kind
    cfg = BSAConfig(
        dim=64, num_heads=4, num_blocks=1, ball_size=min(256, n), kernels=kernels, **kw
    )
    params = jax.eval_shape(
        lambda s: model.attn_layer_init(jax.random.PRNGKey(s), cfg, kind), jnp.int32(0)
    )
    flat, tree = jax.tree_util.tree_flatten(params)
    pnames = _flat_names(params)
    nparams = len(flat)
    x_spec = jax.ShapeDtypeStruct((1, n, cfg.dim), jnp.float32)

    def attn_flat(*args):
        p = jax.tree_util.tree_unflatten(tree, args[:nparams])
        return (model.attn_layer_forward(layer, p, args[nparams], cfg),)

    tag = f"{kind}_n{n}" + ("_ref" if kernels != "pallas" else "")
    fname = f"attn_{tag}.hlo.txt"
    wrote = _emit(out_dir, fname, lambda: jax.jit(attn_flat).lower(*flat, x_spec), force)
    mf.graph(
        f"attn_{tag}", fname, "attn", tag, cfg, n, 1, nparams,
        list(flat) + [x_spec], [x_spec],
        in_names=pnames + ["x"], out_names=["out"],
    )
    print(f"  attn_{tag}: {'wrote' if wrote else 'cached'}")

    # init for the layer params (benches need concrete weights)
    def init_flat(seed):
        return tuple(
            jax.tree_util.tree_leaves(model.attn_layer_init(jax.random.PRNGKey(seed), cfg, kind))
        )

    fname = f"attninit_{tag}.hlo.txt"
    wrote = _emit(out_dir, fname, lambda: jax.jit(init_flat).lower(jax.ShapeDtypeStruct((), jnp.int32)), force)
    mf.graph(
        f"attninit_{tag}", fname, "init", tag, cfg, n, 1, nparams,
        [jax.ShapeDtypeStruct((), jnp.int32)], list(flat),
        in_names=["seed"], out_names=pnames,
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--suite", default="core", choices=["core", "bench", "all"])
    ap.add_argument("--scaling", action="store_true", help="emit F3/F4 attn graphs")
    ap.add_argument("--max-n", type=int, default=16384, help="cap scaling N")
    ap.add_argument("--kinds", default=",".join(SCALING_KINDS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    mf = ManifestWriter()

    for spec in suite_specs(args.suite):
        print(f"[{spec.tag}]")
        lower_spec(spec, args.out, mf, args.force)

    if args.scaling:
        for kind in args.kinds.split(","):
            for n in SCALING_NS:
                if n > args.max_n:
                    continue
                lower_attn(kind, n, args.out, mf, args.force, kernels="pallas")
                lower_attn(kind, n, args.out, mf, args.force, kernels="ref")

    mf.write(os.path.join(args.out, "manifest.txt"))
    print(f"manifest: {os.path.join(args.out, 'manifest.txt')}")


if __name__ == "__main__":
    main()
