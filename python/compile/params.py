"""Shared hyperparameter containers for the BSA stack.

These mirror the paper's Table 4 defaults:

    Ball size                       256
    Compression block size            8
    Compression block sliding stride  8   (= block size: non-overlapping)
    Selection block size              8
    Number of blocks selected (k*)    4

and the training setup of Appendix A (AdamW, lr 1e-3, wd 0.01, cosine
schedule, MSE loss, 18 blocks of RMSNorm -> BSA -> SwiGLU).

The same dataclass is serialized into artifacts/manifest.txt by aot.py and
parsed by the rust runtime (rust/src/runtime/manifest.rs), so field names
here are part of the artifact interface.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class BSAConfig:
    """Architecture + sparse-attention hyperparameters."""

    # -- transformer
    dim: int = 64                 # model width C
    num_heads: int = 4            # attention heads H (head dim = dim // H)
    num_blocks: int = 6           # transformer depth (paper: 18)
    in_features: int = 6          # input features per point (coords+normals)
    out_features: int = 1         # regression targets per point
    mlp_ratio: int = 4            # SwiGLU hidden expansion

    # -- sparse attention (paper Table 4)
    ball_size: int = 256          # m: BTA ball size
    cmp_block: int = 8            # l: compression block size (stride = l)
    sel_block: int = 8            # selection block size (= l in the paper)
    top_k: int = 4                # k*: number of selected blocks
    group_size: int = 8           # g: group-selection size |G_p|

    # -- variants (paper Table 3 rows)
    group_select: bool = True     # False => "BSA w/o group selection"
    group_compress: bool = False  # True  => "BSA w group compression"
    mlp_compress: bool = False    # phi = MLP instead of mean pooling
    mask_own_ball: bool = True    # mask selection blocks inside own ball

    # kernel backend: "pallas" (interpret-mode kernels) or "ref" (pure jnp)
    kernels: str = "pallas"

    @property
    def head_dim(self) -> int:
        return self.dim // self.num_heads

    def validate(self, n: int) -> None:
        """Check the divisibility contract the kernels rely on."""
        if self.dim % self.num_heads != 0:
            raise ValueError(f"dim {self.dim} % heads {self.num_heads} != 0")
        if n % self.ball_size != 0:
            raise ValueError(f"N={n} not divisible by ball size {self.ball_size}")
        if self.ball_size % self.cmp_block != 0:
            raise ValueError("ball size must be divisible by cmp block")
        if self.ball_size % self.group_size != 0:
            raise ValueError("ball size must be divisible by group size")
        if n % self.cmp_block != 0 or n % self.group_size != 0:
            raise ValueError("N must be divisible by cmp block and group size")
        n_blocks = n // self.cmp_block
        if self.top_k > n_blocks:
            raise ValueError(f"top_k {self.top_k} > number of blocks {n_blocks}")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Optimizer / schedule hyperparameters (paper Appendix A)."""

    lr: float = 1e-3
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    # The cosine schedule itself is computed host-side in rust and fed as a
    # scalar input each step, keeping the lowered train_step graph static.
