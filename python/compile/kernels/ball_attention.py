"""Pallas kernel: Ball Tree Attention (paper eq. 3).

Dense attention *within* disjoint balls of ``ball_size`` tokens. The rust
coordinator orders points with a ball tree (rust/src/balltree.rs) so that
every contiguous chunk of ``ball_size`` leaf positions is one ball; the
kernel therefore sees a perfectly regular blocked problem.

TPU mapping (see DESIGN.md §Hardware-Adaptation): one grid step per
(sequence, ball); the whole ball's Q, K, V tiles live in VMEM
(3 * m * d * 4B ≈ 0.2 MB at m=256, d=64) and the m×m score tile
(256 KB) stays in registers/VMEM — a single fused MXU matmul pair with a
VPU softmax between. No masking and no ragged edges by construction.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so lowering happens through the Pallas interpreter, which
emits plain HLO (while/dynamic-slice) runnable from the rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ball_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    """One ball: softmax(Q K^T * scale) V, all operands VMEM-resident."""
    q = q_ref[0]  # (m, d)
    k = k_ref[0]
    v = v_ref[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # numerically-stable softmax on the VPU
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("ball_size",))
def ball_attention(q, k, v, ball_size):
    """Ball Tree Attention. q, k, v: (S, N, d) -> (S, N, d).

    Requires N % ball_size == 0 (guaranteed by the rust ball-tree pad).
    """
    s, n, d = q.shape
    assert n % ball_size == 0, (n, ball_size)
    nb = n // ball_size
    scale = 1.0 / d ** 0.5

    spec = pl.BlockSpec((1, ball_size, d), lambda si, bi: (si, bi, 0))
    return pl.pallas_call(
        functools.partial(_ball_kernel, scale=scale),
        grid=(s, nb),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((s, n, d), q.dtype),
        interpret=True,
    )(q, k, v)
