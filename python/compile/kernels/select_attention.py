"""Pallas kernel: grouped selection attention (paper eqs. 8, 10-12).

Each contiguous group of ``group`` queries shares one set of ``k*``
selected KV blocks (group selection); the kernel gathers those blocks with
dynamic slices and runs a dense (group × k*·block) attention.

This is the branch NSA aligns to hardware and the paper leaves as future
work ("we do not implement a Triton kernel for efficient selection") — the
kernel here is that missing piece, expressed for the TPU memory system:

  * group selection makes every gather a *contiguous* ``block × d`` slice
    (one VMEM DMA each, double-buffered on real hardware) instead of k*·l
    scattered row reads;
  * the per-group attention is a dense (g × k*l) @ (k*l × d) MXU pair —
    with the paper's g=8, k*=4, l=8 this is below the 128×128 systolic
    tile, so multiple groups would be batched per MXU pass on real TPU
    (noted in DESIGN.md §Perf); the interpreter executes it as-is.

Top-k index computation stays at L2 (model.py) in plain XLA: it is a
control-heavy argmax cascade that the MXU cannot help with, and NSA
likewise computes indices outside the gather kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _select_kernel(q_ref, k_ref, v_ref, idx_ref, o_ref, *, sel_block, top_k, scale):
    qg = q_ref[0]  # (group, d)
    g, d = qg.shape

    # Gather k* contiguous KV blocks — unrolled (top_k is static).
    ks = []
    vs = []
    for j in range(top_k):
        start = idx_ref[0, 0, j] * sel_block
        ks.append(pl.load(k_ref, (0, pl.ds(start, sel_block), slice(None))))
        vs.append(pl.load(v_ref, (0, pl.ds(start, sel_block), slice(None))))
    ksel = jnp.concatenate(ks, axis=0)  # (k*·block, d)
    vsel = jnp.concatenate(vs, axis=0)

    s = jnp.dot(qg, ksel.T, preferred_element_type=jnp.float32) * scale
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, vsel, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("sel_block", "group"))
def select_attention(q, k, v, idx, sel_block, group):
    """Grouped top-k block attention.

    q, k, v: (S, N, d); idx: (S, N/group, k*) int32 block indices
    (ascending within a group — see ref.ref_topk_indices). Returns
    (S, N, d). ``group=1`` gives the per-token "BSA w/o group selection"
    variant of Table 3.
    """
    s, n, d = q.shape
    g_cnt = n // group
    assert n % group == 0
    assert idx.shape[:2] == (s, g_cnt), (idx.shape, s, g_cnt)
    top_k = idx.shape[-1]
    scale = 1.0 / d ** 0.5

    q_spec = pl.BlockSpec((1, group, d), lambda si, gi: (si, gi, 0))
    kv_spec = pl.BlockSpec((1, n, d), lambda si, gi: (si, 0, 0))
    idx_spec = pl.BlockSpec((1, 1, top_k), lambda si, gi: (si, gi, 0))
    return pl.pallas_call(
        functools.partial(
            _select_kernel, sel_block=sel_block, top_k=top_k, scale=scale
        ),
        grid=(s, g_cnt),
        in_specs=[q_spec, kv_spec, kv_spec, idx_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((s, n, d), q.dtype),
        interpret=True,
    )(q, k, v, idx)
