"""Pallas kernels: block compression phi (paper eq. 5 / 13).

Maps non-overlapping blocks of ``block`` tokens to a single coarse token,
either by mean pooling (regular BSA) or by a 2-layer GELU MLP over the
flattened block (the phi used with group compression, paper Sec. 3.1).

TPU mapping: grid walks (sequence, block-tile); each step loads
``tile`` consecutive blocks (tile*block × d) into VMEM and reduces them —
a pure-VPU reshape+mean for the pooling variant, a (tile × block*d) @
(block*d × hidden) @ (hidden × d) MXU pair for the MLP. Both are
bandwidth-bound; the tile size amortises grid overhead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mean_kernel(x_ref, o_ref, *, block):
    xt = x_ref[0]  # (tile*block, d)
    tb, d = xt.shape
    o_ref[0] = xt.reshape(tb // block, block, d).mean(axis=1)


@functools.partial(jax.jit, static_argnames=("block", "tile"))
def compress_mean(x, block, tile=64):
    """Mean-pool blocks. x: (S, N, d) -> (S, N/block, d)."""
    s, n, d = x.shape
    assert n % block == 0
    nb = n // block
    tile = min(tile, nb)
    assert nb % tile == 0, (nb, tile)

    in_spec = pl.BlockSpec((1, tile * block, d), lambda si, bi: (si, bi, 0))
    out_spec = pl.BlockSpec((1, tile, d), lambda si, bi: (si, bi, 0))
    return pl.pallas_call(
        functools.partial(_mean_kernel, block=block),
        grid=(s, nb // tile),
        in_specs=[in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((s, nb, d), x.dtype),
        interpret=True,
    )(x)


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, *, block):
    xt = x_ref[0]  # (tile*block, d)
    tb, d = xt.shape
    xb = xt.reshape(tb // block, block * d)
    h = jax.nn.gelu(
        jnp.dot(xb, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...]
    )
    o_ref[0] = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32) + b2_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "tile"))
def compress_mlp(x, block, w1, b1, w2, b2, tile=64):
    """MLP phi over flattened blocks. x: (S, N, d) -> (S, N/block, d).

    w1: (block*d, hidden), b1: (hidden,), w2: (hidden, d), b2: (d,) —
    shared across sequences/heads (broadcast into every grid step's VMEM).
    """
    s, n, d = x.shape
    assert n % block == 0
    nb = n // block
    tile = min(tile, nb)
    assert nb % tile == 0, (nb, tile)
    hidden = w1.shape[1]

    in_spec = pl.BlockSpec((1, tile * block, d), lambda si, bi: (si, bi, 0))
    out_spec = pl.BlockSpec((1, tile, d), lambda si, bi: (si, bi, 0))
    w1_spec = pl.BlockSpec((block * d, hidden), lambda si, bi: (0, 0))
    b1_spec = pl.BlockSpec((hidden,), lambda si, bi: (0,))
    w2_spec = pl.BlockSpec((hidden, d), lambda si, bi: (0, 0))
    b2_spec = pl.BlockSpec((d,), lambda si, bi: (0,))
    return pl.pallas_call(
        functools.partial(_mlp_kernel, block=block),
        grid=(s, nb // tile),
        in_specs=[in_spec, w1_spec, b1_spec, w2_spec, b2_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((s, nb, d), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)
