"""Pure-jnp reference oracle for every BSA kernel.

This file is the CORE correctness signal of the stack: each Pallas kernel in
this package must match its `ref_*` counterpart to float32 tolerance
(pytest: python/tests/test_kernels.py, hypothesis sweeps over shapes).

All attention functions operate on stacked head-major tensors of shape
``(S, N, d)`` where ``S = batch * heads``; the model layer (model.py) folds
batch and head dims before calling in here.

Notation follows the paper (Sec. 2): ball size ``m``, compression block
``l``, selection group ``g``, ``k*`` selected blocks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # mask value; large-but-finite avoids NaN in all-masked rows


def softmax_attention(q, k, v, scale=None):
    """Dense scaled-dot-product attention. q:(...,Nq,d) k,v:(...,Nk,d)."""
    d = q.shape[-1]
    scale = (1.0 / d ** 0.5) if scale is None else scale
    scores = jnp.einsum("...qd,...kd->...qk", q, k) * scale
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", probs, v)


# ---------------------------------------------------------------------------
# Ball Tree Attention (paper eq. 3)
# ---------------------------------------------------------------------------

def ref_ball_attention(q, k, v, ball_size):
    """Full attention inside disjoint balls of ``ball_size`` tokens.

    q, k, v: (S, N, d) with N % ball_size == 0 (rust guarantees this by
    ball-tree padding). Returns (S, N, d).
    """
    s, n, d = q.shape
    nb = n // ball_size
    qb = q.reshape(s, nb, ball_size, d)
    kb = k.reshape(s, nb, ball_size, d)
    vb = v.reshape(s, nb, ball_size, d)
    ob = softmax_attention(qb, kb, vb)
    return ob.reshape(s, n, d)


# ---------------------------------------------------------------------------
# Compression branch (paper eq. 5): block pooling phi
# ---------------------------------------------------------------------------

def ref_compress_mean(x, block):
    """Mean-pool non-overlapping blocks. (S, N, d) -> (S, N/block, d)."""
    s, n, d = x.shape
    return x.reshape(s, n // block, block, d).mean(axis=2)


def ref_compress_mlp(x, block, w1, b1, w2, b2):
    """MLP phi over flattened blocks: (S,N,d) -> (S, N/block, d).

    w1: (block*d, hidden), b1: (hidden,), w2: (hidden, d), b2: (d,).
    """
    s, n, d = x.shape
    xb = x.reshape(s, n // block, block * d)
    h = jax.nn.gelu(xb @ w1 + b1)
    return h @ w2 + b2


def ref_compressed_attention(q, kc, vc):
    """Attend queries against the compressed KV: Attn(Q, K^cmp, V^cmp)."""
    return softmax_attention(q, kc, vc)


# ---------------------------------------------------------------------------
# Selection branch (paper eqs. 6-8, 10-12)
# ---------------------------------------------------------------------------

def ref_group_scores(q, kc, group):
    """Group-averaged importance scores S-bar (paper eq. 12).

    Because the dot product is linear, averaging per-token scores over a
    group equals scoring with the group-mean query:
        mean_t <q_t, k_j> = <mean_t q_t, k_j>.
    q: (S, N, d), kc: (S, NB, d) -> (S, N/group, NB).
    """
    s, n, d = q.shape
    qg = q.reshape(s, n // group, group, d).mean(axis=2)
    return jnp.einsum("...gd,...bd->...gb", qg, kc)


def ref_ball_mask(scores, group, cmp_block, ball_size):
    """Mask scores of blocks that lie inside the query group's own ball.

    Encourages selection to reach *outside* the ball already covered by BTA
    (paper Sec. 3.2, receptive-field discussion). scores: (S, G, NB).
    """
    s, g_cnt, nb = scores.shape
    group_ball = (jnp.arange(g_cnt) * group) // ball_size          # (G,)
    block_ball = (jnp.arange(nb) * cmp_block) // ball_size         # (NB,)
    same = group_ball[:, None] == block_ball[None, :]              # (G, NB)
    return jnp.where(same[None, :, :], NEG_INF, scores)


def ref_topk_indices(scores, k):
    """Top-k block indices per group, ascending-sorted for contiguous DMA.

    Implemented as k rounds of argmax-and-suppress rather than
    ``jax.lax.top_k``: jax >= 0.6 lowers top_k to a dedicated ``topk`` HLO
    instruction that the AOT toolchain's XLA (xla_extension 0.5.1) cannot
    parse, while argmax/one_hot/sort lower to classic HLO. k is small and
    static (k* = 4 in the paper), so the Python loop fully unrolls.
    """
    s = scores
    cols = s.shape[-1]
    picks = []
    for _ in range(k):
        i = jnp.argmax(s, axis=-1)
        picks.append(i)
        s = s - jax.nn.one_hot(i, cols, dtype=s.dtype) * 2e30
    idx = jnp.stack(picks, axis=-1)
    return jnp.sort(idx, axis=-1).astype(jnp.int32)


def ref_select_attention(q, k, v, idx, sel_block, group):
    """Attend each query group against its selected KV blocks.

    q, k, v: (S, N, d); idx: (S, N/group, k*) int32 block indices into
    blocks of ``sel_block`` tokens. All queries in group p share idx[p].
    Returns (S, N, d).
    """
    s, n, d = q.shape
    g_cnt = n // group
    kst = idx.shape[-1]

    kb = k.reshape(s, n // sel_block, sel_block, d)
    vb = v.reshape(s, n // sel_block, sel_block, d)

    # gather: (S, G, k*, sel_block, d)
    gather = jax.vmap(  # over S
        jax.vmap(  # over groups
            lambda blocks, ids: blocks[ids],  # (NB, sel_block, d), (k*,)
            in_axes=(None, 0),
        ),
        in_axes=(0, 0),
    )
    ksel = gather(kb, idx).reshape(s, g_cnt, kst * sel_block, d)
    vsel = gather(vb, idx).reshape(s, g_cnt, kst * sel_block, d)

    qg = q.reshape(s, g_cnt, group, d)
    og = softmax_attention(qg, ksel, vsel)
    return og.reshape(s, n, d)


# ---------------------------------------------------------------------------
# Full BSA layer (paper eq. 9) — used as the oracle for the fused model path
# ---------------------------------------------------------------------------

def ref_bsa_attention(
    q,
    k,
    v,
    *,
    ball_size,
    cmp_block,
    group_size,
    top_k,
    group_select=True,
    group_compress=False,
    mask_own_ball=True,
    gates=None,
    cmp_params=None,
):
    """Reference for the whole three-branch BSA attention (heads folded).

    gates: optional tuple of three (S, N, 1) per-branch sigmoid gates
    (already in [0,1]); defaults to all-ones (ungated sum) for kernel tests.
    cmp_params: optional (w1, b1, w2, b2) for MLP compression; mean if None.
    Returns (S, N, d).
    """
    s, n, d = q.shape

    # compression branch
    if cmp_params is None:
        kc = ref_compress_mean(k, cmp_block)
        vc = ref_compress_mean(v, cmp_block)
    else:
        kc = ref_compress_mlp(k, cmp_block, *cmp_params)
        vc = ref_compress_mlp(v, cmp_block, *cmp_params)

    if group_compress:
        if cmp_params is None:
            qc = ref_compress_mean(q, cmp_block)
        else:
            qc = ref_compress_mlp(q, cmp_block, *cmp_params)
        oc = ref_compressed_attention(qc, kc, vc)
        o_cmp = jnp.repeat(oc, cmp_block, axis=1)  # (I (x) 1_l) repeat
    else:
        o_cmp = ref_compressed_attention(q, kc, vc)

    # selection branch
    g = group_size if group_select else 1
    scores = ref_group_scores(q, kc, g)
    if mask_own_ball:
        scores = ref_ball_mask(scores, g, cmp_block, ball_size)
    idx = ref_topk_indices(scores, top_k)
    idx = jax.lax.stop_gradient(idx)
    o_slc = ref_select_attention(q, k, v, idx, cmp_block, g)

    # ball branch
    o_ball = ref_ball_attention(q, k, v, ball_size)

    if gates is None:
        return o_ball + o_cmp + o_slc
    return gates[0] * o_ball + gates[1] * o_cmp + gates[2] * o_slc
