"""Pallas kernel: tiled (flash-style) dense attention.

Used twice in the stack:
  * the **compressed branch** of BSA — queries attend to the pooled
    K^cmp/V^cmp of length N/l (paper eq. 5), and
  * the **Full Attention baseline** (Tables 1-3, Figures 3-4).

TPU mapping: the grid walks (sequence, query-tile); each step streams the
query tile (Tq × d) into VMEM and loops over KV tiles with the classic
online-softmax accumulator (running max + normaliser), so the N×N score
matrix is never materialised. For the compressed branch the whole KV
(N/l × d ≈ 128 KB at N=4096, l=8, d=64) is VMEM-resident and the inner
loop has a single iteration.

The KV tensor for one sequence is mapped into the kernel whole; on a real
TPU the inner `pl.load` dynamic slices become double-buffered VMEM DMAs.
For the *baseline at very large N* (Fig. 3's 65536) the whole-KV residency
would exceed VMEM on TPU — noted in DESIGN.md; the baseline is exercised
through the interpreter on CPU where this is only a working-set question.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, kv_tile):
    q = q_ref[0]  # (tq, d)
    tq, d = q.shape
    nk = k_ref.shape[1]
    steps = nk // kv_tile

    def body(i, carry):
        acc, m_run, l_run = carry
        kt = pl.load(k_ref, (0, pl.ds(i * kv_tile, kv_tile), slice(None)))
        vt = pl.load(v_ref, (0, pl.ds(i * kv_tile, kv_tile), slice(None)))
        s = jnp.dot(q, kt.T, preferred_element_type=jnp.float32) * scale
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.dot(p, vt, preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((tq, d), jnp.float32)
    m0 = jnp.full((tq, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((tq, 1), jnp.float32)
    acc, _, l_run = jax.lax.fori_loop(0, steps, body, (acc0, m0, l0))
    o_ref[0] = (acc / l_run).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q_tile", "kv_tile"))
def flash_attention(q, k, v, q_tile=128, kv_tile=128):
    """Tiled attention. q: (S, Nq, d); k, v: (S, Nk, d) -> (S, Nq, d)."""
    s, nq, d = q.shape
    _, nk, _ = k.shape
    q_tile = min(q_tile, nq)
    kv_tile = min(kv_tile, nk)
    assert nq % q_tile == 0 and nk % kv_tile == 0, (nq, q_tile, nk, kv_tile)
    scale = 1.0 / d ** 0.5

    q_spec = pl.BlockSpec((1, q_tile, d), lambda si, qi: (si, qi, 0))
    kv_spec = pl.BlockSpec((1, nk, d), lambda si, qi: (si, 0, 0))
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, kv_tile=kv_tile),
        grid=(s, nq // q_tile),
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((s, nq, d), q.dtype),
        interpret=True,
    )(q, k, v)
