"""Numpy mirror of the native gradient kernels (rust/src/backend/grad/).

The Rust backward pass cannot be run in CI without a toolchain, but its
math can: every gradient kernel in ``rust/src/backend/grad/`` is a
transcription of a formula in this file, and this file checks each
formula two ways:

* against **finite differences** of the matching forward, in float64
  (central differences, eps 1e-6 — truncation ~1e-12, roundoff ~1e-10,
  so the 1e-5 relative tolerance here is tight, not hopeful);
* where jax is importable, the composite three-branch attention
  backward is additionally checked against ``jax.grad`` of the repo's
  own reference oracle (``python/compile/kernels/ref.py`` —
  ``ref_bsa_attention`` with sigmoid gates and its
  ``stop_gradient``-wrapped top-k index set). CI installs only numpy,
  so the jax cross-check self-skips there; it runs wherever the AOT
  toolchain is present.

Load-bearing claims mirrored from the Rust side:

* **Flash-style backward** (``grad::attention::attend_backward``): the
  backward recomputes the per-query online softmax stats ``(m_i, l_i)``
  by streaming keys in the same fixed 64-wide tiles as the forward
  (``kernels::STREAM_TILE``), then forms ``p_ij = exp(s_ij - m_i)/l_i``
  tile by tile — the ``nq x nk`` probability matrix is never
  materialized, in either direction. With ``D_i = <dO_i, O_i>``:
  ``dS_ij = p_ij (<dO_i, V_j> - D_i)``, ``dQ_i = scale * sum_j dS_ij K_j``,
  ``dK_j = scale * sum_i dS_ij Q_i``, ``dV_j = sum_i p_ij dO_i``.
* **Straight-through top-k**: the selection branch's block indices are
  a stop-gradient index set (matching ``ref_topk_indices`` +
  ``jax.lax.stop_gradient`` in ref.py). No gradient flows through the
  group scores, the group-mean queries, or the own-ball mask; the
  selected K/V blocks still receive gradient through the gathered
  attention itself. Finite differences agree because argmax indices
  are locally constant in the inputs (ties are measure-zero).
* **RMSNorm backward** (eps shared with ``linalg::RMS_EPS``):
  ``y_i = x_i * inv * s_i`` with ``inv = (mean(x^2) + eps)^(-1/2)`` gives
  ``dx_j = dy_j inv s_j - x_j inv^3 / C * sum_i dy_i s_i x_i`` and
  ``dscale_i = sum_rows dy_i x_i inv``.
* **SwiGLU backward**: ``g = silu(h1) * h3`` with
  ``silu'(x) = sig(x) (1 + x (1 - sig(x)))``.
* **Gated merge backward**: ``merge = sum_b sig(t_b) o_b`` over the
  three branches gives ``dt_b = sig(t_b)(1 - sig(t_b)) <dmerge, o_b>``
  and ``do_b = sig(t_b) dmerge`` per token per head.
* **Mean-pool compression backward**: transpose of the block mean —
  every token row of a block receives ``dOut_block / block``.
* **Adam**: bias-corrected moments with decoupled (AdamW-style) weight
  decay; the first step moves each weight by ``~ -lr * sign(g)``.
"""

from __future__ import annotations

import numpy as np
import pytest

NEG_INF = -1e30
STREAM_TILE = 64
RMS_EPS = 1e-6


# ---------------------------------------------------------------------------
# forward mirrors (float64 oracles of the rust forward kernels)
# ---------------------------------------------------------------------------


def softmax_attend(q, k, v, scale):
    """Dense scaled-dot-product attention, (nq,d)x(nk,d) -> (nq,d)."""
    s = (q @ k.T) * scale
    s = s - s.max(axis=1, keepdims=True)
    p = np.exp(s)
    p = p / p.sum(axis=1, keepdims=True)
    return p @ v


def stream_stats(q, k, scale):
    """Per-query online (max, exp-sum) in fixed 64-wide key tiles.

    Transcribes the forward's running-max/rescale recurrence
    (kernels::stream_row); the backward recomputes exactly these stats
    instead of saving an nq x nk score matrix.
    """
    nq = q.shape[0]
    nk = k.shape[0]
    m = np.full(nq, -np.inf)
    l = np.zeros(nq)
    for i in range(nq):
        mi, li = -np.inf, 0.0
        for t0 in range(0, nk, STREAM_TILE):
            s = (k[t0 : t0 + STREAM_TILE] @ q[i]) * scale
            tmax = s.max()
            if tmax == -np.inf:
                continue
            if tmax > mi:
                if li > 0.0:
                    li *= np.exp(mi - tmax)
                mi = tmax
            li += np.exp(s - mi).sum()
        m[i], l[i] = mi, li
    return m, l


def rms_norm(x, scale):
    inv = 1.0 / np.sqrt((x * x).mean(axis=1) + RMS_EPS)
    return x * inv[:, None] * scale[None, :]


def sigmoid(x):
    return 1.0 / (1.0 + np.exp(-x))


def silu(x):
    return x * sigmoid(x)


def compress_mean(x, block):
    n, d = x.shape
    return x.reshape(n // block, block, d).mean(axis=1)


def topk_rows(scores, k):
    """Argmax-and-suppress top-k, ascending-sorted (kernels::topk_row)."""
    out = []
    for row in scores.copy():
        picks = []
        for _ in range(k):
            best = int(np.argmax(row))  # first index on ties
            picks.append(best)
            row[best] -= 2e30
        out.append(sorted(picks))
    return np.array(out, dtype=np.int64)


def mask_own_ball(scores, group, cmp_block, ball):
    g_cnt, nb = scores.shape
    out = scores.copy()
    for gi in range(g_cnt):
        for bi in range(nb):
            if (gi * group) // ball == (bi * cmp_block) // ball:
                out[gi, bi] = NEG_INF
    return out


# ---------------------------------------------------------------------------
# backward mirrors (the formulas rust/src/backend/grad/ implements)
# ---------------------------------------------------------------------------


def attend_backward(q, k, v, o, dout, scale):
    """Flash-style attention backward; never materializes p as (nq,nk).

    The rust kernel runs pass B query-parallel and pass C key-parallel
    (each output row owned by one thread, ascending inner order) so the
    result is bitwise reproducible across thread counts; the math per
    element is exactly this.
    """
    nq, _ = q.shape
    nk = k.shape[0]
    m, l = stream_stats(q, k, scale)
    d_coef = np.einsum("id,id->i", dout, o)  # D_i = <dO_i, O_i>
    dq = np.zeros_like(q)
    dk = np.zeros_like(k)
    dv = np.zeros_like(v)
    for i in range(nq):
        if l[i] <= 0.0:
            # forward fell back to the uniform value mean (defensive
            # path: unreachable without masks since the running max
            # keeps exp(0)=1 in the sum) -> o = mean(v), dS = 0
            dv += dout[i][None, :] / nk
            continue
        for t0 in range(0, nk, STREAM_TILE):
            kt = k[t0 : t0 + STREAM_TILE]
            vt = v[t0 : t0 + STREAM_TILE]
            s = (kt @ q[i]) * scale
            p = np.exp(s - m[i]) / l[i]
            dp = vt @ dout[i]
            ds = p * (dp - d_coef[i])
            dq[i] += scale * (ds @ kt)
            dk[t0 : t0 + STREAM_TILE] += scale * np.outer(ds, q[i])
            dv[t0 : t0 + STREAM_TILE] += np.outer(p, dout[i])
    return dq, dk, dv


def rms_norm_backward(x, scale, dy):
    rows, c = x.shape
    inv = 1.0 / np.sqrt((x * x).mean(axis=1) + RMS_EPS)
    dscale = (dy * x * inv[:, None]).sum(axis=0)
    proj = (dy * scale[None, :] * x).sum(axis=1)
    dx = dy * scale[None, :] * inv[:, None] - x * (inv**3 / c * proj)[:, None]
    return dx, dscale


def swiglu_backward(h1, h3, dg):
    sg = sigmoid(h1)
    dh1 = dg * h3 * (sg * (1.0 + h1 * (1.0 - sg)))
    dh3 = dg * (h1 * sg)
    return dh1, dh3


def merge_backward(logits, branches, dmerge):
    """logits (n,3), branches 3x(n,d), dmerge (n,d) -> (dlogits, dbranches)."""
    sg = sigmoid(logits)
    dlogits = np.stack(
        [
            sg[:, b] * (1.0 - sg[:, b]) * np.einsum("nd,nd->n", dmerge, branches[b])
            for b in range(3)
        ],
        axis=1,
    )
    dbranches = [sg[:, b : b + 1] * dmerge for b in range(3)]
    return dlogits, dbranches


def compress_mean_backward(dout, block, n):
    nb, d = dout.shape
    assert nb * block == n
    return np.repeat(dout, block, axis=0) / block


# ---------------------------------------------------------------------------
# composite: one attention unit (one batch sample x one head), mirroring
# NativeBackend::attention's per-unit body and grad::tape's per-unit
# backward
# ---------------------------------------------------------------------------


def unit_forward(qs, ks, vs, logits, ball, cmp_block, group, top_k):
    n, dh = qs.shape
    scale = 1.0 / np.sqrt(dh)
    nb = n // cmp_block
    g_cnt = n // group

    o_ball = np.zeros_like(qs)
    for b0 in range(0, n, ball):
        o_ball[b0 : b0 + ball] = softmax_attend(
            qs[b0 : b0 + ball], ks[b0 : b0 + ball], vs[b0 : b0 + ball], scale
        )

    kc = compress_mean(ks, cmp_block)
    vc = compress_mean(vs, cmp_block)
    o_cmp = softmax_attend(qs, kc, vc, scale)

    qg = qs.reshape(g_cnt, group, dh).mean(axis=1)
    gscores = qg @ kc.T  # unscaled, like kernels::group_scores
    gscores = mask_own_ball(gscores, group, cmp_block, ball)
    idx = topk_rows(gscores, top_k)

    o_slc = np.zeros_like(qs)
    for p in range(g_cnt):
        ksel = np.concatenate([ks[j * cmp_block : (j + 1) * cmp_block] for j in idx[p]])
        vsel = np.concatenate([vs[j * cmp_block : (j + 1) * cmp_block] for j in idx[p]])
        o_slc[p * group : (p + 1) * group] = softmax_attend(
            qs[p * group : (p + 1) * group], ksel, vsel, scale
        )

    sg = sigmoid(logits)
    merge = sg[:, 0:1] * o_ball + sg[:, 1:2] * o_cmp + sg[:, 2:3] * o_slc
    return merge, (o_ball, o_cmp, o_slc, kc, vc, idx)


def unit_backward(qs, ks, vs, logits, dmerge, ball, cmp_block, group, top_k):
    n, dh = qs.shape
    scale = 1.0 / np.sqrt(dh)
    _, (o_ball, o_cmp, o_slc, kc, vc, idx) = unit_forward(
        qs, ks, vs, logits, ball, cmp_block, group, top_k
    )
    dlogits, (d_ball, d_cmp, d_slc) = merge_backward(
        logits, [o_ball, o_cmp, o_slc], dmerge
    )

    dq = np.zeros_like(qs)
    dk = np.zeros_like(ks)
    dv = np.zeros_like(vs)

    # ball branch: disjoint balls, q and k rows both ball-local
    for b0 in range(0, n, ball):
        sl = slice(b0, b0 + ball)
        dqb, dkb, dvb = attend_backward(
            qs[sl], ks[sl], vs[sl], o_ball[sl], d_ball[sl], scale
        )
        dq[sl] += dqb
        dk[sl] += dkb
        dv[sl] += dvb

    # compression branch: attend over pooled KV, then the pool transpose
    dqc, dkc, dvc = attend_backward(qs, kc, vc, o_cmp, d_cmp, scale)
    dq += dqc
    dk += compress_mean_backward(dkc, cmp_block, n)
    dv += compress_mean_backward(dvc, cmp_block, n)
    # straight-through: kc also feeds the group scores, but the top-k
    # index set is stop-gradient — nothing flows back through gscores

    # selection branch: per group, gather -> attend -> scatter-add
    g_cnt = n // group
    for p in range(g_cnt):
        gsl = slice(p * group, (p + 1) * group)
        ksel = np.concatenate([ks[j * cmp_block : (j + 1) * cmp_block] for j in idx[p]])
        vsel = np.concatenate([vs[j * cmp_block : (j + 1) * cmp_block] for j in idx[p]])
        dqg, dksel, dvsel = attend_backward(
            qs[gsl], ksel, vsel, o_slc[gsl], d_slc[gsl], scale
        )
        dq[gsl] += dqg
        for t, j in enumerate(idx[p]):
            jsl = slice(j * cmp_block, (j + 1) * cmp_block)
            tsl = slice(t * cmp_block, (t + 1) * cmp_block)
            dk[jsl] += dksel[tsl]
            dv[jsl] += dvsel[tsl]

    return dq, dk, dv, dlogits


# ---------------------------------------------------------------------------
# finite-difference harness
# ---------------------------------------------------------------------------

EPS = 1e-6


def fd_grad(f, x, eps=EPS):
    """Elementwise central-difference gradient of scalar f at x (f64)."""
    g = np.zeros_like(x)
    flat = x.reshape(-1)
    gflat = g.reshape(-1)
    for i in range(flat.size):
        orig = flat[i]
        flat[i] = orig + eps
        fp = f()
        flat[i] = orig - eps
        fm = f()
        flat[i] = orig
        gflat[i] = (fp - fm) / (2.0 * eps)
    return g


def assert_grads_close(analytic, numeric, label):
    np.testing.assert_allclose(
        analytic, numeric, rtol=1e-5, atol=1e-8, err_msg=f"{label} gradient mismatch"
    )


# ---------------------------------------------------------------------------
# kernel-level tests
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "nq,nk,d",
    [
        (1, 1, 1),  # degenerate
        (4, 7, 3),  # sub-tile
        (8, 64, 4),  # exactly one tile
        (5, 65, 4),  # tile tail of 1
        (6, 130, 3),  # two tiles + tail
    ],
)
def test_attend_backward_matches_fd(nq, nk, d):
    rng = np.random.default_rng(nq * 1000 + nk * 10 + d)
    q = rng.standard_normal((nq, d))
    k = rng.standard_normal((nk, d))
    v = rng.standard_normal((nk, d))
    w = rng.standard_normal((nq, d))  # loss = sum(w * attend(q,k,v))
    scale = 1.0 / np.sqrt(d)

    def loss():
        return float((w * softmax_attend(q, k, v, scale)).sum())

    o = softmax_attend(q, k, v, scale)
    dq, dk, dv = attend_backward(q, k, v, o, w, scale)
    assert_grads_close(dq, fd_grad(loss, q), "attend dq")
    assert_grads_close(dk, fd_grad(loss, k), "attend dk")
    assert_grads_close(dv, fd_grad(loss, v), "attend dv")


def test_attend_backward_adversarial_rescale_chain():
    """Scores ramp upward across tiles so the online max rescales often —
    the regime where a wrong (m, l) recomputation diverges fastest."""
    rng = np.random.default_rng(7)
    nq, nk, d = 3, 150, 4
    q = rng.standard_normal((nq, d))
    k = rng.standard_normal((nk, d)) + np.linspace(0, 6, nk)[:, None]
    v = rng.standard_normal((nk, d))
    w = rng.standard_normal((nq, d))
    scale = 1.0 / np.sqrt(d)

    def loss():
        return float((w * softmax_attend(q, k, v, scale)).sum())

    o = softmax_attend(q, k, v, scale)
    dq, dk, dv = attend_backward(q, k, v, o, w, scale)
    assert_grads_close(dq, fd_grad(loss, q), "ramp dq")
    assert_grads_close(dk, fd_grad(loss, k), "ramp dk")
    assert_grads_close(dv, fd_grad(loss, v), "ramp dv")


def test_stream_stats_match_dense_softmax():
    rng = np.random.default_rng(11)
    q = rng.standard_normal((5, 4))
    k = rng.standard_normal((130, 4)) * 3.0
    scale = 0.5
    m, l = stream_stats(q, k, scale)
    s = (q @ k.T) * scale
    # rtol: matrix-matrix vs matrix-vector BLAS paths differ by ~1 ulp
    np.testing.assert_allclose(m, s.max(axis=1), rtol=1e-14)
    np.testing.assert_allclose(
        l, np.exp(s - s.max(axis=1, keepdims=True)).sum(axis=1), rtol=1e-12
    )


def test_rms_norm_backward_matches_fd():
    rng = np.random.default_rng(3)
    rows, c = 6, 9
    x = rng.standard_normal((rows, c))
    scale = rng.standard_normal(c) + 1.0
    w = rng.standard_normal((rows, c))

    def loss():
        return float((w * rms_norm(x, scale)).sum())

    dx, dscale = rms_norm_backward(x, scale, w)
    assert_grads_close(dx, fd_grad(loss, x), "rms dx")
    assert_grads_close(dscale, fd_grad(loss, scale), "rms dscale")


def test_rms_norm_backward_near_zero_rows():
    """The eps term keeps inv finite on an all-zeros row; the gradient
    there must still match FD (inv = eps^-1/2, large but finite)."""
    rng = np.random.default_rng(4)
    x = rng.standard_normal((3, 5)) * 1e-4
    x[1] = 0.0
    scale = rng.standard_normal(5)
    w = rng.standard_normal((3, 5))

    def loss():
        return float((w * rms_norm(x, scale)).sum())

    dx, dscale = rms_norm_backward(x, scale, w)
    assert_grads_close(dx, fd_grad(loss, x, eps=1e-8), "rms0 dx")
    assert_grads_close(dscale, fd_grad(loss, scale, eps=1e-8), "rms0 dscale")


def test_swiglu_backward_matches_fd():
    rng = np.random.default_rng(5)
    h1 = rng.standard_normal((4, 6)) * 2.0
    h3 = rng.standard_normal((4, 6))
    w = rng.standard_normal((4, 6))

    def loss():
        return float((w * (silu(h1) * h3)).sum())

    dh1, dh3 = swiglu_backward(h1, h3, w)
    assert_grads_close(dh1, fd_grad(loss, h1), "swiglu dh1")
    assert_grads_close(dh3, fd_grad(loss, h3), "swiglu dh3")


def test_gated_merge_backward_matches_fd():
    rng = np.random.default_rng(6)
    n, d = 5, 4
    logits = rng.standard_normal((n, 3)) * 2.0
    branches = [rng.standard_normal((n, d)) for _ in range(3)]
    w = rng.standard_normal((n, d))

    def loss():
        sg = sigmoid(logits)
        out = sum(sg[:, b : b + 1] * branches[b] for b in range(3))
        return float((w * out).sum())

    dlogits, dbranches = merge_backward(logits, branches, w)
    assert_grads_close(dlogits, fd_grad(loss, logits), "merge dlogits")
    for b in range(3):
        assert_grads_close(dbranches[b], fd_grad(loss, branches[b]), f"merge do{b}")


def test_compress_mean_backward_matches_fd():
    rng = np.random.default_rng(8)
    n, d, block = 12, 3, 4
    x = rng.standard_normal((n, d))
    w = rng.standard_normal((n // block, d))

    def loss():
        return float((w * compress_mean(x, block)).sum())

    dx = compress_mean_backward(w, block, n)
    assert_grads_close(dx, fd_grad(loss, x), "compress dx")


# ---------------------------------------------------------------------------
# composite unit test: the full three-branch attention backward
# ---------------------------------------------------------------------------

UNIT = dict(n=32, dh=4, ball=8, cmp_block=4, group=4, top_k=3)


def _unit_inputs(seed):
    rng = np.random.default_rng(seed)
    n, dh = UNIT["n"], UNIT["dh"]
    qs = rng.standard_normal((n, dh))
    ks = rng.standard_normal((n, dh))
    vs = rng.standard_normal((n, dh))
    logits = rng.standard_normal((n, 3))
    w = rng.standard_normal((n, dh))
    return qs, ks, vs, logits, w


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_unit_backward_matches_fd(seed):
    qs, ks, vs, logits, w = _unit_inputs(seed)
    ball, cmp_block, group, top_k = (
        UNIT["ball"],
        UNIT["cmp_block"],
        UNIT["group"],
        UNIT["top_k"],
    )

    def loss():
        merge, _ = unit_forward(qs, ks, vs, logits, ball, cmp_block, group, top_k)
        return float((w * merge).sum())

    dq, dk, dv, dlogits = unit_backward(
        qs, ks, vs, logits, w, ball, cmp_block, group, top_k
    )
    # FD sees the same zero gradient through the top-k path because the
    # argmax index set is locally constant (straight-through semantics)
    assert_grads_close(dq, fd_grad(loss, qs), "unit dq")
    assert_grads_close(dk, fd_grad(loss, ks), "unit dk")
    assert_grads_close(dv, fd_grad(loss, vs), "unit dv")
    assert_grads_close(dlogits, fd_grad(loss, logits), "unit dlogits")


def test_unit_backward_matches_jax_reference():
    """Cross-check the composite backward against jax.grad of the repo's
    reference oracle (ref_bsa_attention, sigmoid gates, stop-gradient
    top-k). Skips when jax is not installed (CI runs numpy only)."""
    jax = pytest.importorskip("jax")
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from compile.kernels import ref

    jax.config.update("jax_enable_x64", True)
    jnp = jax.numpy

    qs, ks, vs, logits, w = _unit_inputs(42)
    ball, cmp_block, group, top_k = (
        UNIT["ball"],
        UNIT["cmp_block"],
        UNIT["group"],
        UNIT["top_k"],
    )

    def jloss(q, k, v, lg):
        gates = tuple(
            jax.nn.sigmoid(lg[:, b])[None, :, None] for b in range(3)
        )  # 3 x (S=1, N, 1)
        out = ref.ref_bsa_attention(
            q[None],
            k[None],
            v[None],
            ball_size=ball,
            cmp_block=cmp_block,
            group_size=group,
            top_k=top_k,
            gates=gates,
        )
        return (jnp.asarray(w)[None] * out).sum()

    jq, jk, jv, jlg = jax.grad(jloss, argnums=(0, 1, 2, 3))(qs, ks, vs, logits)
    dq, dk, dv, dlogits = unit_backward(
        qs, ks, vs, logits, w, ball, cmp_block, group, top_k
    )
    assert_grads_close(dq, np.asarray(jq), "jax dq")
    assert_grads_close(dk, np.asarray(jk), "jax dk")
    assert_grads_close(dv, np.asarray(jv), "jax dv")
    assert_grads_close(dlogits, np.asarray(jlg), "jax dlogits")


# ---------------------------------------------------------------------------
# Adam (grad::adam) — bias-corrected moments, decoupled weight decay
# ---------------------------------------------------------------------------


def adam_step(p, g, m, v, t, lr, beta1=0.9, beta2=0.999, eps=1e-8, wd=0.0):
    """One AdamW step, t is the 1-based step count (rust grad::adam)."""
    m = beta1 * m + (1.0 - beta1) * g
    v = beta2 * v + (1.0 - beta2) * g * g
    mhat = m / (1.0 - beta1**t)
    vhat = v / (1.0 - beta2**t)
    p = p - lr * (mhat / (np.sqrt(vhat) + eps) + wd * p)
    return p, m, v


def test_adam_first_step_is_sign_descent():
    rng = np.random.default_rng(9)
    p = rng.standard_normal(16)
    g = rng.standard_normal(16)
    p1, m, v = adam_step(p.copy(), g, np.zeros(16), np.zeros(16), t=1, lr=1e-3)
    # bias correction makes mhat = g, vhat = g^2 on step one, so the
    # update is lr * g / (|g| + eps) ~ lr * sign(g)
    np.testing.assert_allclose(p - p1, 1e-3 * np.sign(g), rtol=1e-5, atol=1e-9)
    np.testing.assert_allclose(m, 0.1 * g, rtol=1e-12)
    np.testing.assert_allclose(v, 0.001 * g * g, rtol=1e-12)


def test_adam_decoupled_weight_decay():
    p = np.array([2.0, -4.0])
    g = np.zeros(2)
    m = np.zeros(2)
    v = np.zeros(2)
    # zero gradient: only the decoupled decay moves the weights,
    # multiplicatively, independent of the moment state
    p1, _, _ = adam_step(p.copy(), g, m, v, t=1, lr=0.1, wd=0.01)
    np.testing.assert_allclose(p1, p * (1.0 - 0.1 * 0.01), rtol=1e-12)


def test_adam_converges_on_quadratic():
    """End-to-end sanity: Adam minimizes a simple quadratic, and the
    moment state round-trips through a save/restore split (the .bsackpt
    v3 resume contract: moments + step restore => identical trajectory)."""
    target = np.array([1.0, -2.0, 3.0])
    p = np.zeros(3)
    m = np.zeros(3)
    v = np.zeros(3)
    losses = []
    for t in range(1, 201):
        g = 2.0 * (p - target)
        losses.append(float(((p - target) ** 2).sum()))
        p, m, v = adam_step(p, g, m, v, t=t, lr=0.05)
    assert losses[-1] < 1e-2 * losses[0]

    # split run: 100 steps, "checkpoint" (p, m, v, t), 100 more — must
    # equal the unbroken 200-step run bit for bit
    p2 = np.zeros(3)
    m2 = np.zeros(3)
    v2 = np.zeros(3)
    for t in range(1, 101):
        g = 2.0 * (p2 - target)
        p2, m2, v2 = adam_step(p2, g, m2, v2, t=t, lr=0.05)
    saved = (p2.copy(), m2.copy(), v2.copy())
    p3, m3, v3 = saved
    for t in range(101, 201):
        g = 2.0 * (p3 - target)
        p3, m3, v3 = adam_step(p3, g, m3, v3, t=t, lr=0.05)
    np.testing.assert_array_equal(p3, p)
    np.testing.assert_array_equal(m3, m)
    np.testing.assert_array_equal(v3, v)
