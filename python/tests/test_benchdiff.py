"""Tests for scripts/benchdiff.py — the rebar-style cross-run perf
artifact differ that scripts/check.sh prints after refreshing
BENCH_native.json / BENCH_serve.json.

Import-level tests on the flatten/diff/regression logic plus one
subprocess round trip of the CLI exit-code contract (0 informational,
2 on --fail-over regression). numpy-free on purpose: this suite runs in
the CI python-mirror job with nothing but pytest installed.
"""

import importlib.util
import json
import pathlib
import subprocess
import sys

SCRIPT = pathlib.Path(__file__).resolve().parents[2] / "scripts" / "benchdiff.py"

spec = importlib.util.spec_from_file_location("benchdiff", SCRIPT)
benchdiff = importlib.util.module_from_spec(spec)
spec.loader.exec_module(benchdiff)


OLD = {
    "bench": "bsa_native",
    "reps": 5,
    "threads_sweep": [
        {"threads": 1, "p50_us": 1000.0, "fwd_per_s": 10.0},
        {"threads": 2, "p50_us": 600.0, "fwd_per_s": 18.0},
    ],
    "simd": {
        "mode": "avx2",
        "kernels": [
            {"name": "matmul_nt", "scalar_us": 40.0, "simd_us": 10.0, "speedup": 4.0}
        ],
        "e2e": {"threads": 1, "scalar_fwd_per_s": 10.0, "simd_fwd_per_s": 30.0, "speedup": 3.0},
    },
}


def new_doc(fwd1=10.0, p50=1000.0):
    doc = json.loads(json.dumps(OLD))
    doc["threads_sweep"][0]["fwd_per_s"] = fwd1
    doc["threads_sweep"][0]["p50_us"] = p50
    return doc


# the `shard` section `bsa loadgen` merges into BENCH_serve.json
SHARD_OLD = {
    "bench": "serve_hot_path",
    "reps": 3,
    "shard": {
        "requests": 200,
        "geometries": 8,
        "offered_per_s": 100.0,
        "achieved_per_s": 98.0,
        "shed_rate": 0.02,
        "p50_us": 900.0,
        "p99_us": 4000.0,
        "workers": {
            "w0": {"tree_hits": 90, "tree_misses": 4, "hit_ratio": 0.957},
            "w1": {"tree_hits": 88, "tree_misses": 4, "hit_ratio": 0.956},
        },
    },
}


def shard_doc(shed=0.02, hit0=0.957, p99=4000.0):
    doc = json.loads(json.dumps(SHARD_OLD))
    doc["shard"]["shed_rate"] = shed
    doc["shard"]["workers"]["w0"]["hit_ratio"] = hit0
    doc["shard"]["p99_us"] = p99
    return doc


def test_flatten_keys_lists_by_identity_field():
    flat = benchdiff.flatten(OLD)
    assert flat["threads_sweep[threads=1].fwd_per_s"] == 10.0
    assert flat["simd.kernels[name=matmul_nt].simd_us"] == 10.0
    # descriptors and strings are not measurements
    assert "reps" not in flat
    assert "bench" not in flat
    assert "simd.mode" not in flat


def test_direction_classification():
    assert benchdiff.direction("threads_sweep[threads=1].fwd_per_s") == "higher"
    assert benchdiff.direction("x.speedup") == "higher"
    assert benchdiff.direction("pool.saved_us") == "higher"  # before the _us rule
    assert benchdiff.direction("x.p50_us") == "lower"
    assert benchdiff.direction("preprocess.cached.p95_us") == "lower"
    assert benchdiff.direction("router.tree_hits") == "higher"
    assert benchdiff.direction("router.tree_misses") == "lower"
    assert benchdiff.direction("trace_overhead.overhead_pct") == "lower"
    assert benchdiff.direction("arch.depth") is None


def test_diff_reports_deltas_and_verdicts():
    rows, skipped = benchdiff.diff(OLD, new_doc(fwd1=8.0, p50=1250.0))
    by_path = {r[0]: r for r in rows}
    path, old, new, delta, verdict = by_path["threads_sweep[threads=1].fwd_per_s"]
    assert (old, new) == (10.0, 8.0)
    assert abs(delta - (-20.0)) < 1e-9
    assert verdict == "worse"
    _, _, _, delta, verdict = by_path["threads_sweep[threads=1].p50_us"]
    assert abs(delta - 25.0) < 1e-9
    assert verdict == "worse"
    # untouched metrics are "~"
    assert by_path["simd.kernels[name=matmul_nt].speedup"][4] == "~"
    assert skipped == 0


def test_null_leaves_are_skipped_not_compared():
    placeholder = json.loads(json.dumps(OLD))
    placeholder["threads_sweep"][0]["fwd_per_s"] = None
    rows, skipped = benchdiff.diff(placeholder, OLD)
    assert skipped >= 1
    assert all(r[0] != "threads_sweep[threads=1].fwd_per_s" for r in rows)


def test_regressions_respect_direction_and_threshold():
    rows, _ = benchdiff.diff(OLD, new_doc(fwd1=8.0))  # -20% on higher-better
    regs = benchdiff.regressions(rows, 10.0)
    assert [r[0] for r in regs] == ["threads_sweep[threads=1].fwd_per_s"]
    assert benchdiff.regressions(rows, 25.0) == []
    # an improvement never trips the gate
    rows, _ = benchdiff.diff(OLD, new_doc(fwd1=20.0))
    assert benchdiff.regressions(rows, 10.0) == []


def test_section_filter():
    rows, _ = benchdiff.diff(OLD, new_doc(fwd1=8.0), section="simd")
    assert rows and all(r[0].startswith("simd") for r in rows)


def test_shard_section_directions():
    assert benchdiff.direction("shard.shed_rate") == "lower"
    assert benchdiff.direction("shard.workers.w0.hit_ratio") == "higher"
    assert benchdiff.direction("shard.offered_per_s") == "higher"
    assert benchdiff.direction("shard.p99_us") == "lower"
    assert benchdiff.direction("shard.workers.w0.tree_hits") == "higher"


def test_shard_section_flattens_with_descriptors_skipped():
    flat = benchdiff.flatten(SHARD_OLD)
    assert flat["shard.shed_rate"] == 0.02
    assert flat["shard.workers.w0.hit_ratio"] == 0.957
    # run descriptors stay out of the metric set
    assert "shard.requests" not in flat
    assert "shard.geometries" not in flat


def test_shard_regressions_shed_up_and_hit_ratio_down_are_worse():
    rows, _ = benchdiff.diff(SHARD_OLD, shard_doc(shed=0.08))
    regs = benchdiff.regressions(rows, 10.0)
    assert [r[0] for r in regs] == ["shard.shed_rate"]

    rows, _ = benchdiff.diff(SHARD_OLD, shard_doc(hit0=0.50))
    regs = benchdiff.regressions(rows, 10.0)
    assert [r[0] for r in regs] == ["shard.workers.w0.hit_ratio"]

    # a shed-rate *drop* is an improvement, never a regression
    rows, _ = benchdiff.diff(SHARD_OLD, shard_doc(shed=0.001))
    assert benchdiff.regressions(rows, 10.0) == []


def test_shard_null_placeholder_is_skipped():
    # paper.rs seeds `"shard": null` until the first loadgen run; the
    # differ must treat that as absent, not as a comparison
    placeholder = json.loads(json.dumps(SHARD_OLD))
    placeholder["shard"] = None
    rows, _ = benchdiff.diff(placeholder, SHARD_OLD)
    assert all(not r[0].startswith("shard") for r in rows)
    rows, _ = benchdiff.diff(SHARD_OLD, placeholder)
    assert all(not r[0].startswith("shard") for r in rows)


def test_shard_section_filter_isolates_serving_tier():
    rows, _ = benchdiff.diff(SHARD_OLD, shard_doc(p99=8000.0), section="shard")
    assert rows and all(r[0].startswith("shard") for r in rows)
    by_path = {r[0]: r for r in rows}
    assert by_path["shard.p99_us"][4] == "worse"


# the `train_step` section paper.rs level 10 writes into BENCH_native.json
TRAIN_OLD = {
    "bench": "bsa_native",
    "reps": 3,
    "train_step": {
        "arch": {"dim": 32, "heads": 2, "blocks": 2, "ball": 64, "n": 256, "batch": 1},
        "steps": 12,
        "steps_per_s": 4.0,
        "grad_peak_rss_mb": 120.0,
        "rss_reset": True,
        "loss_first": 1.2,
        "loss_last": 0.8,
    },
}


def train_doc(sps=4.0, rss=120.0):
    doc = json.loads(json.dumps(TRAIN_OLD))
    doc["train_step"]["steps_per_s"] = sps
    doc["train_step"]["grad_peak_rss_mb"] = rss
    return doc


def test_train_step_directions():
    assert benchdiff.direction("train_step.steps_per_s") == "higher"
    assert benchdiff.direction("train_step.grad_peak_rss_mb") == "lower"


def test_train_step_flattens_with_descriptors_skipped():
    flat = benchdiff.flatten(TRAIN_OLD)
    assert flat["train_step.steps_per_s"] == 4.0
    assert flat["train_step.grad_peak_rss_mb"] == 120.0
    # arch fields, step count, and the rss_reset bool are descriptors
    assert "train_step.steps" not in flat
    assert "train_step.arch.dim" not in flat
    assert "train_step.rss_reset" not in flat


def test_train_step_regressions_are_direction_aware():
    # throughput drop trips the gate
    rows, _ = benchdiff.diff(TRAIN_OLD, train_doc(sps=3.0))
    regs = benchdiff.regressions(rows, 10.0)
    assert [r[0] for r in regs] == ["train_step.steps_per_s"]
    # gradient-memory growth trips the gate
    rows, _ = benchdiff.diff(TRAIN_OLD, train_doc(rss=200.0))
    regs = benchdiff.regressions(rows, 10.0)
    assert [r[0] for r in regs] == ["train_step.grad_peak_rss_mb"]
    # faster + leaner never trips it
    rows, _ = benchdiff.diff(TRAIN_OLD, train_doc(sps=8.0, rss=60.0))
    assert benchdiff.regressions(rows, 10.0) == []


def test_train_step_null_placeholder_is_skipped():
    # the committed pre-toolchain BENCH_native.json carries null
    # steps_per_s / grad_peak_rss_mb until the first measured run
    placeholder = json.loads(json.dumps(TRAIN_OLD))
    placeholder["train_step"]["steps_per_s"] = None
    placeholder["train_step"]["grad_peak_rss_mb"] = None
    placeholder["train_step"]["loss_first"] = None
    placeholder["train_step"]["loss_last"] = None
    rows, skipped = benchdiff.diff(placeholder, TRAIN_OLD)
    assert skipped >= 2
    assert all(not r[0].startswith("train_step") for r in rows)


def test_cli_exit_codes(tmp_path):
    old_p = tmp_path / "old.json"
    new_p = tmp_path / "new.json"
    old_p.write_text(json.dumps(OLD))
    new_p.write_text(json.dumps(new_doc(fwd1=8.0)))

    # informational mode always exits 0 and prints a table
    run = subprocess.run(
        [sys.executable, str(SCRIPT), str(old_p), str(new_p), "--label", "t"],
        capture_output=True,
        text=True,
    )
    assert run.returncode == 0, run.stderr
    assert "fwd_per_s" in run.stdout and "worse" in run.stdout

    # --fail-over trips on the 20% regression
    run = subprocess.run(
        [sys.executable, str(SCRIPT), str(old_p), str(new_p), "--fail-over", "10"],
        capture_output=True,
        text=True,
    )
    assert run.returncode == 2
    assert "regressed" in run.stderr

    # unreadable input is a clean error, not a traceback
    run = subprocess.run(
        [sys.executable, str(SCRIPT), str(tmp_path / "missing.json"), str(new_p)],
        capture_output=True,
        text=True,
    )
    assert run.returncode == 1
    assert "cannot read" in run.stderr
