"""Kernel-vs-oracle correctness: every Pallas kernel against kernels/ref.py.

Hypothesis sweeps the shape/parameter space (S, N, d, block sizes); each
kernel must match the pure-jnp oracle to f32 tolerance. This is the core
correctness signal the custom-vjp training path relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.ball_attention import ball_attention
from compile.kernels.flash_attention import flash_attention
from compile.kernels.compress import compress_mean, compress_mlp
from compile.kernels.select_attention import select_attention

ATOL = 2e-5
RTOL = 2e-5


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


def assert_close(a, b, atol=ATOL, rtol=RTOL):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


# ---------------------------------------------------------------------------
# ball attention
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    s=st.integers(1, 4),
    balls=st.integers(1, 4),
    m=st.sampled_from([16, 32, 64]),
    d=st.sampled_from([8, 16, 32]),
)
def test_ball_attention_matches_ref(s, balls, m, d):
    n = balls * m
    q, k, v = (rand(i, (s, n, d)) for i in range(3))
    assert_close(ball_attention(q, k, v, m), ref.ref_ball_attention(q, k, v, m))


def test_ball_attention_is_block_diagonal():
    """Perturbing tokens in ball j must not change outputs in ball i != j."""
    s, m, d = 1, 32, 8
    n = 4 * m
    q, k, v = (rand(i, (s, n, d)) for i in range(3))
    base = ball_attention(q, k, v, m)
    k2 = k.at[:, 3 * m :, :].add(100.0)
    v2 = v.at[:, 3 * m :, :].add(-50.0)
    pert = ball_attention(q, k2, v2, m)
    assert_close(base[:, : 3 * m], pert[:, : 3 * m])
    assert float(jnp.abs(base[:, 3 * m :] - pert[:, 3 * m :]).max()) > 1e-3


def test_ball_attention_single_ball_equals_dense():
    s, n, d = 2, 64, 16
    q, k, v = (rand(i, (s, n, d)) for i in range(3))
    assert_close(ball_attention(q, k, v, n), ref.softmax_attention(q, k, v))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    s=st.integers(1, 3),
    nq=st.sampled_from([32, 64, 128]),
    nk=st.sampled_from([32, 64, 256]),
    d=st.sampled_from([8, 32]),
    q_tile=st.sampled_from([16, 32, 128]),
    kv_tile=st.sampled_from([16, 32]),
)
def test_flash_matches_dense(s, nq, nk, d, q_tile, kv_tile):
    q = rand(0, (s, nq, d))
    k = rand(1, (s, nk, d))
    v = rand(2, (s, nk, d))
    out = flash_attention(q, k, v, q_tile=q_tile, kv_tile=kv_tile)
    assert_close(out, ref.softmax_attention(q, k, v))


def test_flash_extreme_logits_stable():
    """Online softmax must survive large score magnitudes (no inf/nan)."""
    s, n, d = 1, 64, 16
    q = rand(0, (s, n, d), scale=30.0)
    k = rand(1, (s, n, d), scale=30.0)
    v = rand(2, (s, n, d))
    out = flash_attention(q, k, v, q_tile=32, kv_tile=32)
    assert np.isfinite(np.asarray(out)).all()
    assert_close(out, ref.softmax_attention(q, k, v), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    s=st.integers(1, 4),
    nb=st.sampled_from([8, 16, 64]),
    block=st.sampled_from([4, 8, 16]),
    d=st.sampled_from([8, 32]),
    tile=st.sampled_from([4, 8, 64]),
)
def test_compress_mean_matches_ref(s, nb, block, d, tile):
    if nb % min(tile, nb) != 0:
        return
    x = rand(0, (s, nb * block, d))
    assert_close(compress_mean(x, block, tile=tile), ref.ref_compress_mean(x, block))


@settings(max_examples=10, deadline=None)
@given(
    s=st.integers(1, 3),
    nb=st.sampled_from([8, 16]),
    block=st.sampled_from([4, 8]),
    d=st.sampled_from([8, 16]),
    hidden=st.sampled_from([16, 32]),
)
def test_compress_mlp_matches_ref(s, nb, block, d, hidden):
    x = rand(0, (s, nb * block, d))
    w1 = rand(1, (block * d, hidden), 0.1)
    b1 = rand(2, (hidden,), 0.1)
    w2 = rand(3, (hidden, d), 0.1)
    b2 = rand(4, (d,), 0.1)
    out = compress_mlp(x, block, w1, b1, w2, b2, tile=8)
    assert_close(out, ref.ref_compress_mlp(x, block, w1, b1, w2, b2), atol=1e-4)


def test_compress_mean_of_constant_blocks():
    """Pooling constant blocks returns the constants exactly."""
    s, nb, block, d = 2, 8, 8, 16
    vals = jnp.arange(nb, dtype=jnp.float32)
    x = jnp.broadcast_to(vals[None, :, None, None], (s, nb, block, d)).reshape(
        s, nb * block, d
    )
    out = compress_mean(x, block)
    assert_close(out, jnp.broadcast_to(vals[None, :, None], (s, nb, d)))


# ---------------------------------------------------------------------------
# selection attention
# ---------------------------------------------------------------------------

def _make_idx(key, s, g_cnt, n_blocks, k):
    scores = jax.random.normal(jax.random.PRNGKey(key), (s, g_cnt, n_blocks))
    return ref.ref_topk_indices(scores, k)


@settings(max_examples=12, deadline=None)
@given(
    s=st.integers(1, 3),
    n=st.sampled_from([64, 128, 256]),
    block=st.sampled_from([4, 8]),
    group=st.sampled_from([1, 4, 8]),
    k=st.sampled_from([1, 2, 4]),
)
def test_select_matches_ref(s, n, block, group, k):
    if k > n // block:
        return
    q, kk, v = (rand(i, (s, n, 8)) for i in range(3))
    idx = _make_idx(7, s, n // group, n // block, k)
    out = select_attention(q, kk, v, idx, block, group)
    assert_close(out, ref.ref_select_attention(q, kk, v, idx, block, group))


def test_select_all_blocks_equals_dense():
    """Selecting every block reproduces dense attention."""
    s, n, block, d = 1, 64, 8, 16
    q, k, v = (rand(i, (s, n, d)) for i in range(3))
    nb = n // block
    idx = jnp.broadcast_to(jnp.arange(nb, dtype=jnp.int32), (s, n, nb))
    out = select_attention(q, k, v, idx, block, 1)
    assert_close(out, ref.softmax_attention(q, k, v))


def test_select_single_block_attends_only_there():
    """With one selected block, output is attention over that block only."""
    s, n, block, d = 1, 64, 8, 8
    q, k, v = (rand(i, (s, n, d)) for i in range(3))
    idx = jnp.full((s, n // 8, 1), 3, dtype=jnp.int32)
    out = select_attention(q, k, v, idx, block, 8)
    kb = k[:, 24:32]
    vb = v[:, 24:32]
    expect = ref.softmax_attention(q, kb, vb)
    assert_close(out, expect)


# ---------------------------------------------------------------------------
# scoring / masking / topk invariants
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([64, 128, 256]),
    group=st.sampled_from([4, 8, 16]),
    cmp=st.sampled_from([4, 8]),
    ball=st.sampled_from([32, 64]),
)
def test_ball_mask_blocks_own_ball_only(n, group, cmp, ball):
    s = 2
    scores = jnp.zeros((s, n // group, n // cmp))
    masked = ref.ref_ball_mask(scores, group, cmp, ball)
    gm = np.asarray(masked[0])
    for p in range(n // group):
        for j in range(n // cmp):
            same = (p * group) // ball == (j * cmp) // ball
            assert (gm[p, j] < -1e29) == same


def test_group_scores_equal_mean_of_token_scores():
    """Linearity: group-pooled-Q scores == mean of per-token scores."""
    s, n, d, g = 2, 64, 16, 8
    q = rand(0, (s, n, d))
    kc = rand(1, (s, 8, d))
    grp = ref.ref_group_scores(q, kc, g)
    tok = ref.ref_group_scores(q, kc, 1)  # per-token
    manual = tok.reshape(s, n // g, g, -1).mean(axis=2)
    assert_close(grp, manual)


def test_topk_indices_sorted_and_unique():
    s, g_cnt, nb, k = 2, 16, 32, 4
    scores = rand(0, (s, g_cnt, nb))
    idx = np.asarray(ref.ref_topk_indices(scores, k))
    assert (np.diff(idx, axis=-1) > 0).all()  # strictly ascending => unique
    assert idx.min() >= 0 and idx.max() < nb


def test_topk_picks_argmax():
    s, g_cnt, nb = 1, 4, 16
    scores = jnp.zeros((s, g_cnt, nb)).at[:, :, 5].set(10.0)
    idx = np.asarray(ref.ref_topk_indices(scores, 1))
    assert (idx == 5).all()
