"""Numpy mirror of the streaming-attention and half-precision numerics
(rust/src/backend/kernels.rs ``stream_row`` + rust/src/half.rs).

The Rust side's conformance gate (rust/tests/conformance.rs) asserts the
streaming kernel against the materialized oracle on the real binaries;
this file re-derives the two load-bearing numeric claims in exact
float32, so they are checkable on hosts without a Rust toolchain:

1. the **online-softmax rescale identity**: processing keys tile by tile
   with a running max ``m``, exp-sum ``l``, and accumulator rescaled by
   ``alpha = exp(m_old - m_new)`` whenever a later tile raises the max
   produces the same attention output as materializing all scores and
   applying one full softmax — exactly in real arithmetic, within 1e-5
   in float32 across tile-tail widths and adversarial rescale chains.
   The mirror below transcribes ``stream_row``'s update order
   statement-for-statement (skip-tile on all--inf, uniform fallback when
   ``l == 0``), so a change to the Rust loop's structure should be
   re-derived here before loosening the Rust tolerances;

2. the **binary16 conversion algorithm** in half.rs (round-to-nearest-
   even encode including the subnormal range, exact decode) agrees
   bit-for-bit with numpy.float16's hardware/compiler-backed conversion
   on every tested pattern, and its round-trip stays within the
   documented 2^-11 relative bound for normals — the basis of the f16
   forward tolerance tier in backend/mod.rs "Kernel conformance".
"""

import math

import numpy as np

f32 = np.float32

STREAM_TILE = 64  # kernels.rs: fixed key-tile width
NEG_INF = f32(-1e30)  # kernels.rs mask value (finite on purpose)


# ---------------------------------------------------------------------------
# part 1: online-softmax streaming attention mirror
# ---------------------------------------------------------------------------


def attend_reference(q, k, v, scale):
    """Materialized oracle: full scores, one softmax, float64 math."""
    s = (q.astype(np.float64) @ k.astype(np.float64).T) * float(scale)
    s = s - s.max(axis=1, keepdims=True)
    e = np.exp(s)
    w = e / e.sum(axis=1, keepdims=True)
    return (w @ v.astype(np.float64)).astype(f32)


def stream_row(qrow, k, v, scale):
    """Exact-f32 transcription of kernels.rs stream_row (scalar level)."""
    nk, d = k.shape
    m = -math.inf
    l = f32(0.0)
    orow = np.zeros(d, dtype=f32)
    j0 = 0
    while j0 < nk:
        tl = min(STREAM_TILE, nk - j0)
        # tile_scores_at: per-key scaled dot products
        tile = np.empty(tl, dtype=f32)
        for jj in range(tl):
            acc = f32(0.0)
            for x, y in zip(qrow, k[j0 + jj]):
                acc = f32(acc + f32(f32(x) * f32(y)))
            tile[jj] = f32(acc * f32(scale))
        tmax = float(tile.max())
        if tmax == -math.inf:
            j0 += tl
            continue
        if tmax > m:
            if l > 0.0:
                alpha = f32(np.exp(f32(m - tmax)))
                orow = (orow * alpha).astype(f32)
                l = f32(l * alpha)
            m = tmax
        weights = np.exp((tile - f32(m)).astype(f32)).astype(f32)
        for w in weights:
            l = f32(l + w)
        for jj in range(tl):
            orow = (orow + weights[jj] * v[j0 + jj].astype(f32)).astype(f32)
        j0 += tl
    if l > 0.0:
        return (orow * f32(1.0 / l)).astype(f32)
    # every tile was -inf-masked (or nk == 0): uniform value mean
    w = f32(1.0 / nk)
    for j in range(nk):
        orow = (orow + w * v[j].astype(f32)).astype(f32)
    return orow


def test_streaming_matches_full_softmax_at_every_tile_tail():
    rng = np.random.default_rng(3)
    for nk in [1, 2, 7, STREAM_TILE - 1, STREAM_TILE, STREAM_TILE + 1,
               STREAM_TILE + 7, 2 * STREAM_TILE, 2 * STREAM_TILE + 3]:
        q = rng.standard_normal((3, 5)).astype(f32)
        k = rng.standard_normal((nk, 5)).astype(f32)
        v = rng.standard_normal((nk, 5)).astype(f32)
        scale = f32(1.0 / np.sqrt(5.0))
        want = attend_reference(q, k, v, scale)
        for i in range(q.shape[0]):
            got = stream_row(q[i], k, v, scale)
            err = np.max(np.abs(got - want[i]))
            assert err < 1e-5, f"nk={nk} row {i}: max err {err}"


def test_streaming_rescale_chain_with_ascending_maxes():
    # Adversarial for the online rescale: each tile's max strictly above
    # the previous one, so every tile triggers alpha-rescaling of the
    # accumulated output and exp-sum. A bug in the rescale order shows
    # up here and nowhere else.
    rng = np.random.default_rng(9)
    nk = 4 * STREAM_TILE
    d = 6
    q = np.ones((1, d), dtype=f32)
    k = rng.standard_normal((nk, d)).astype(f32) * f32(0.1)
    # plant an ascending spike in each tile: 2, 4, 6, 8 (logit = spike*d)
    for t in range(4):
        k[t * STREAM_TILE + 5] = f32(2.0 * (t + 1))
    v = rng.standard_normal((nk, d)).astype(f32)
    want = attend_reference(q, k, v, f32(1.0))
    got = stream_row(q[0], k, v, f32(1.0))
    assert np.max(np.abs(got - want[0])) < 1e-5


def test_streaming_descending_maxes_never_rescale():
    # The complement: first tile holds the global max, so m never moves
    # after tile 0 and alpha-rescaling must not fire (l > 0 branch with
    # tmax <= m). Exactness of the no-rescale path.
    rng = np.random.default_rng(10)
    nk = 3 * STREAM_TILE
    d = 4
    q = np.ones((1, d), dtype=f32)
    k = rng.standard_normal((nk, d)).astype(f32) * f32(0.1)
    k[3] = f32(5.0)  # global max in tile 0
    v = rng.standard_normal((nk, d)).astype(f32)
    want = attend_reference(q, k, v, f32(1.0))
    got = stream_row(q[0], k, v, f32(1.0))
    assert np.max(np.abs(got - want[0])) < 1e-5


def test_streaming_all_masked_row_is_uniform_not_nan():
    # NEG_INF (finite -1e30) logits: softmax of equal logits is uniform.
    # True -inf logits: every tile is skipped, l stays 0, and the
    # explicit fallback averages the values. Both uniform, both finite.
    rng = np.random.default_rng(12)
    nk = STREAM_TILE + 9
    d = 3
    v = rng.standard_normal((nk, d)).astype(f32)
    mean = v.mean(axis=0).astype(f32)
    for kval in [NEG_INF, f32(-np.inf)]:
        q = np.zeros(d, dtype=f32)
        q[0] = kval
        k = np.zeros((nk, d), dtype=f32)
        k[:, 0] = f32(1.0)  # logit = kval for every key
        got = stream_row(q, k, v, f32(1.0))
        assert np.all(np.isfinite(got)), f"kval={kval}: non-finite"
        assert np.max(np.abs(got - mean)) < 1e-4, f"kval={kval}: not uniform"


def test_streaming_single_key_is_value_passthrough():
    rng = np.random.default_rng(13)
    q = rng.standard_normal(5).astype(f32)
    k = rng.standard_normal((1, 5)).astype(f32)
    v = rng.standard_normal((1, 5)).astype(f32)
    got = stream_row(q, k, v, f32(0.7))
    assert np.max(np.abs(got - v[0])) < 1e-6


def test_streaming_huge_logits_stay_finite():
    # A late-tile key with ~1e3 logits: exp(m_old - m_new) underflows the
    # earlier mass to ~0; the streaming result must converge to the
    # winning value row, matching the materialized softmax.
    rng = np.random.default_rng(14)
    nk = 2 * STREAM_TILE + 3
    d = 4
    q = (np.ones(d) * 40.0).astype(f32)
    k = rng.standard_normal((nk, d)).astype(f32)
    k[nk - 1] = f32(30.0)
    v = rng.standard_normal((nk, d)).astype(f32)
    got = stream_row(q, k, v, f32(1.0))
    want = attend_reference(q[None, :], k, v, f32(1.0))[0]
    assert np.all(np.isfinite(got))
    assert np.max(np.abs(got - want)) < 1e-5


# ---------------------------------------------------------------------------
# part 2: binary16 conversion mirror (rust/src/half.rs)
# ---------------------------------------------------------------------------


def f32_to_f16_bits(x):
    """Transcription of half::f32_to_f16_bits (round-to-nearest-even)."""
    bits = int(np.array(x, dtype=f32).view(np.uint32))
    sign = (bits >> 16) & 0x8000
    exp = (bits >> 23) & 0xFF
    mant = bits & 0x007F_FFFF
    if exp == 0xFF:
        return sign | (0x7C00 if mant == 0 else 0x7E00)
    e = exp - 127 + 15
    if e >= 0x1F:
        return sign | 0x7C00
    if e <= 0:
        if e < -10:
            return sign
        m = mant | 0x0080_0000
        shift = 14 - e
        half_ulp = 1 << (shift - 1)
        half = m >> shift
        rem = m & ((1 << shift) - 1)
        if rem > half_ulp or (rem == half_ulp and (half & 1) == 1):
            half += 1
        return sign | half
    half = (e << 10) | (mant >> 13)
    rem = mant & 0x1FFF
    if rem > 0x1000 or (rem == 0x1000 and (half & 1) == 1):
        half += 1
    return sign | half


def f16_bits_to_f32(h):
    """Transcription of half::f16_bits_to_f32 (exact decode)."""
    sign = (h & 0x8000) << 16
    exp = (h >> 10) & 0x1F
    mant = h & 0x03FF
    if exp == 0:
        if mant == 0:
            bits = sign
        else:
            shift = 0
            m = mant
            while m < 0x0400:  # normalize: bring MSB to bit 10
                m <<= 1
                shift += 1
            bits = sign | ((127 - 15 - shift + 1) << 23) | ((m & 0x03FF) << 13)
    elif exp == 0x1F:
        if mant == 0:
            bits = sign | 0x7F80_0000
        else:
            bits = sign | 0x7FC0_0000 | (mant << 13)
    else:
        bits = sign | ((exp + 127 - 15) << 23) | (mant << 13)
    return np.uint32(bits).view(f32)


def _np_f16_bits(x):
    with np.errstate(over="ignore"):  # overflow-to-inf is the point
        return int(np.array(x, dtype=f32).astype(np.float16).view(np.uint16))


def test_encode_matches_numpy_float16_on_samples():
    rng = np.random.default_rng(21)
    samples = list(rng.standard_normal(2000) * 100.0)
    samples += [
        0.0, -0.0, 1.0, -2.0, 65504.0, 65519.0, 65520.0, 1e30, -1e30,
        5.960464477539063e-08,   # smallest subnormal
        2.9802322387695312e-08,  # exactly half of it: ties to even (zero)
        6.103515625e-05,         # smallest normal
        1.0 + 2.0 ** -11,        # tie: even mantissa keeps 1.0
        1.0 + 3.0 * 2.0 ** -11,  # tie: rounds up to even
        1e-10, -1e-10, 3.0e-5, -7.7e-6, float("inf"), float("-inf"),
    ]
    for x in samples:
        ours = f32_to_f16_bits(f32(x))
        theirs = _np_f16_bits(x)
        assert ours == theirs, f"x={x}: ours {ours:#06x} vs numpy {theirs:#06x}"


def test_encode_handles_nan_like_numpy():
    ours = f32_to_f16_bits(f32(np.nan))
    assert (ours & 0x7C00) == 0x7C00 and (ours & 0x03FF) != 0, "not a NaN"


def test_decode_matches_numpy_on_every_bit_pattern():
    # Exhaustive: all 65536 patterns decode to exactly numpy's f32 view.
    all_bits = np.arange(1 << 16, dtype=np.uint16)
    theirs = all_bits.view(np.float16).astype(f32)
    for h in range(1 << 16):
        ours = f16_bits_to_f32(h)
        t = theirs[h]
        if np.isnan(t):
            assert np.isnan(ours), f"{h:#06x}: NaN mismatch"
        else:
            assert ours.view(np.uint32) == t.view(np.uint32), (
                f"{h:#06x}: ours {ours} vs numpy {t}"
            )


def test_roundtrip_relative_error_bound_for_normals():
    # decode(encode(x)) within 2^-11 * |x| across the f16 normal range —
    # the bound the f16 forward tolerance tier is derived from.
    rng = np.random.default_rng(22)
    xs = (rng.standard_normal(5000) * 100.0).astype(f32)
    for x in xs:
        if abs(float(x)) < 6.2e-5 or abs(float(x)) > 65000.0:
            continue
        r = float(f16_bits_to_f32(f32_to_f16_bits(x)))
        assert abs(r - float(x)) <= abs(float(x)) / 2048.0, f"x={x} r={r}"
