"""Numpy mirror of the Rust native parallel kernels' *tiling and chunking
logic* (rust/src/backend/{pool,linalg,kernels}.rs).

The Rust side's conformance gate (rust/tests/conformance.rs) asserts
fast == `*_reference` on the real binaries; this file mirrors the same
index arithmetic — packed-panel GEMM loops, contiguous row chunking,
ball/group/block chunk offsets, argmax-and-suppress top-k — in exact
float32 so the *algorithms* are testable on hosts without a Rust
toolchain. Every loop here is a line-for-line transcription of the Rust
loop nest it names; if an index bug exists in the scheme, it exists in
both and fails here. numpy-only on purpose: no jax import, so it runs
anywhere `pytest python/tests` runs.
"""

import numpy as np

# panel constants mirroring rust/src/backend/linalg.rs
KC = 256
NC = 128
MR = 4

f32 = np.float32


def chunk_rows(rows, threads):
    """Mirror of pool::chunk_rows: contiguous near-equal ranges."""
    t = max(1, min(threads, max(rows, 1)))
    per = (rows + t - 1) // t
    out = []
    start = 0
    while start < rows:
        end = min(start + per, rows)
        out.append((start, end))
        start = end
    return out


def matmul_reference(a, b, m, k, n):
    """Mirror of linalg::matmul_reference (i-k-j, ascending-k adds)."""
    out = np.zeros(m * n, dtype=f32)
    for i in range(m):
        for kk in range(k):
            av = a[i * k + kk]
            for j in range(n):
                out[i * n + j] = f32(out[i * n + j] + f32(av * b[kk * n + j]))
    return out


def matmul_rows_blocked(a, b, m, k, n):
    """Mirror of linalg::matmul_rows_blocked: direct i-k-j when B fits
    one panel (k <= KC and n <= NC), packed KC x NC panels otherwise."""
    if k <= KC and n <= NC:
        return matmul_reference(a, b, m, k, n)
    out = np.zeros(m * n, dtype=f32)
    packed = np.zeros(min(KC, max(k, 1)) * min(NC, n), dtype=f32)
    jc = 0
    while jc < n:
        ncb = min(NC, n - jc)
        kc = 0
        while kc < k:
            kcb = min(KC, k - kc)
            for kk in range(kcb):
                src = (kc + kk) * n + jc
                packed[kk * ncb:(kk + 1) * ncb] = b[src:src + ncb]
            for i in range(m):
                for kk in range(kcb):
                    av = a[i * k + kc + kk]
                    for jj in range(ncb):
                        o = i * n + jc + jj
                        out[o] = f32(out[o] + f32(av * packed[kk * ncb + jj]))
            kc += kcb
        jc += ncb
    return out


def matmul_parallel(a, b, m, k, n, threads):
    """Mirror of linalg::matmul: blocked kernel per contiguous row chunk."""
    out = np.zeros(m * n, dtype=f32)
    for row0, row1 in chunk_rows(m, threads):
        rows = row1 - row0
        out[row0 * n:row1 * n] = matmul_rows_blocked(
            a[row0 * k:row1 * k], b, rows, k, n
        )
    return out


def test_blocked_gemm_bitwise_equals_reference_across_panel_boundaries():
    # k > KC and n > NC force the panel loops to wrap — the exact case
    # the Rust conformance sweep pins, mirrored here bit-for-bit.
    rng = np.random.default_rng(0)
    for (m, k, n) in [(3, KC + 7, NC + 22), (5, 40, 33), (1, 2 * KC + 1, 1), (2, 10, NC + 5)]:
        a = rng.standard_normal(m * k).astype(f32)
        b = rng.standard_normal(k * n).astype(f32)
        ref = matmul_reference(a, b, m, k, n)
        for threads in (1, 2, 3):
            fast = matmul_parallel(a, b, m, k, n, threads)
            assert fast.tobytes() == ref.tobytes(), (
                f"blocked GEMM diverged at m={m} k={k} n={n} threads={threads}"
            )


def test_chunk_rows_partitions_exactly():
    for rows in (0, 1, 7, 23, 64):
        for threads in (1, 2, 3, 8, 64):
            chunks = chunk_rows(rows, threads)
            covered = [i for (s, e) in chunks for i in range(s, e)]
            assert covered == list(range(rows))
            assert len(chunks) <= max(threads, 1)


def softmax_rows(x, rows, cols):
    """Mirror of linalg::softmax_rows_reference (max-subtracted)."""
    out = x.copy()
    for r in range(rows):
        row = out[r * cols:(r + 1) * cols]
        m = row.max()
        e = np.exp(row - m, dtype=f32)
        s = f32(0.0)
        for v in e:
            s = f32(s + v)
        if s > 0:
            row[:] = e / s
    return out


def ball_attention_chunked(q, k, v, n, d, ball, threads):
    """Mirror of kernels::ball_attention's chunk offsets: par_rows over
    balls, absolute ball index = ball0 + bi within each chunk."""
    out = np.zeros(n * d, dtype=f32)
    scale = f32(1.0 / np.sqrt(f32(d)))
    chunk = ball * d
    nballs = n // ball
    for ball0, ball1 in chunk_rows(nballs, threads):
        for b in range(ball0, ball1):
            lo, hi = b * chunk, (b + 1) * chunk
            qb = q[lo:hi].reshape(ball, d)
            kb = k[lo:hi].reshape(ball, d)
            vb = v[lo:hi].reshape(ball, d)
            scores = (qb @ kb.T).astype(f32) * scale
            flat = softmax_rows(scores.reshape(-1), ball, ball).reshape(ball, ball)
            out[lo:hi] = (flat @ vb).astype(f32).reshape(-1)
    return out


def test_ball_chunking_covers_every_ball_once():
    rng = np.random.default_rng(1)
    n, d, ball = 21, 3, 3  # uneven ball size, odd ball count
    q = rng.standard_normal(n * d).astype(f32)
    k = rng.standard_normal(n * d).astype(f32)
    v = rng.standard_normal(n * d).astype(f32)
    ref = ball_attention_chunked(q, k, v, n, d, ball, 1)
    for threads in (2, 3, 5, 8):
        out = ball_attention_chunked(q, k, v, n, d, ball, threads)
        assert out.tobytes() == ref.tobytes(), f"threads={threads}"
    # degenerate single-point balls: softmax over one key => out == v
    out1 = ball_attention_chunked(q, k, v, n, d, 1, 4)
    np.testing.assert_allclose(out1, v, atol=1e-6)


def topk_row(row, k):
    """Mirror of kernels::topk_row (first-max wins, suppress, sort)."""
    row = row.copy()
    out = []
    for _ in range(k):
        best, bv = 0, -np.inf
        for i, val in enumerate(row):
            if val > bv:  # strict > keeps the first occurrence on ties
                bv = val
                best = i
        out.append(best)
        row[best] = f32(row[best] - f32(2e30))
    return sorted(out)


def test_topk_chunking_matches_serial_with_ties():
    rng = np.random.default_rng(2)
    groups, nb, k = 9, 12, 4
    # quantized scores make duplicates (ties) common
    scores = (rng.standard_normal(groups * nb) * 2).round() / 2
    scores = scores.astype(f32)
    serial = [topk_row(scores[g * nb:(g + 1) * nb], k) for g in range(groups)]
    for threads in (2, 3, 8):
        chunked = [None] * groups
        for g0, g1 in chunk_rows(groups, threads):
            for g in range(g0, g1):
                chunked[g] = topk_row(scores[g * nb:(g + 1) * nb], k)
        assert chunked == serial, f"threads={threads}"


def test_compress_chunk_offsets():
    # Mirror of kernels::compress_mean: the chunk starting at block b0
    # reads x[b0*block*d : (b0+blocks)*block*d] — off-by-one in either
    # bound shears every downstream mean.
    rng = np.random.default_rng(3)
    n, d, block = 35, 4, 5  # odd block count, uneven block size
    nb = n // block
    x = rng.standard_normal(n * d).astype(f32)
    ref = x.reshape(nb, block, d).mean(axis=1, dtype=f32).reshape(-1)
    for threads in (1, 2, 3, 8):
        out = np.zeros(nb * d, dtype=f32)
        for b0, b1 in chunk_rows(nb, threads):
            xs = x[b0 * block * d:b1 * block * d].reshape(b1 - b0, block, d)
            out[b0 * d:b1 * d] = xs.mean(axis=1, dtype=f32).reshape(-1)
        np.testing.assert_allclose(out, ref, atol=1e-6)


def test_head_parallel_split_fold_roundtrip():
    # Mirror of the head-parallel scheme in native.rs::attention (PR 4):
    # unit u = bi*H + hd gathers the (N, dh) column slice hd*dh.. of its
    # batch item from the token-major (B*N, C) projection, writes its
    # result into the head-major staging block merged_hm[u*n*dh ..], and
    # a fold pass restores token-major rows. The round-trip must equal
    # the old serial scheme's direct column writes exactly.
    rng = np.random.default_rng(4)
    b, h, n, dh = 2, 3, 8, 4
    c = h * dh
    proj = rng.standard_normal(b * n * c).astype(f32)

    # per-unit transform standing in for the three-branch attention
    # (any per-(token, head) function works; the scheme is what's tested)
    def unit_fn(block, u):
        return (block * f32(2.0) + f32(u)).astype(f32)

    # old serial scheme: direct writes into token-major column slices
    serial = np.zeros(b * n * c, dtype=f32)
    for bi in range(b):
        for hd in range(h):
            col0 = hd * dh
            gathered = np.zeros(n * dh, dtype=f32)
            for t in range(n):
                src = (bi * n + t) * c + col0
                gathered[t * dh:(t + 1) * dh] = proj[src:src + dh]
            res = unit_fn(gathered, bi * h + hd)
            for t in range(n):
                dst = (bi * n + t) * c + col0
                serial[dst:dst + dh] = res[t * dh:(t + 1) * dh]

    # head-parallel scheme: unit-chunked gather -> head-major staging ->
    # row-chunked fold (both chunkings swept over thread counts)
    units = b * h
    for threads in (1, 2, 3, 8):
        merged_hm = np.zeros(units * n * dh, dtype=f32)
        for u0, u1 in chunk_rows(units, threads):
            for u in range(u0, u1):
                bi, hd = u // h, u % h
                col0 = hd * dh
                gathered = np.zeros(n * dh, dtype=f32)
                for t in range(n):
                    src = (bi * n + t) * c + col0
                    gathered[t * dh:(t + 1) * dh] = proj[src:src + dh]
                merged_hm[u * n * dh:(u + 1) * n * dh] = unit_fn(gathered, u)
        merged = np.zeros(b * n * c, dtype=f32)
        for r0, r1 in chunk_rows(b * n, threads):
            for r in range(r0, r1):
                bi, t = r // n, r % n
                for hd in range(h):
                    src = ((bi * h + hd) * n + t) * dh
                    merged[r * c + hd * dh:r * c + (hd + 1) * dh] = \
                        merged_hm[src:src + dh]
        assert np.array_equal(merged, serial), f"threads={threads}"
