"""Semantic properties of the full BSA attention (paper Secs. 2.2, 3.2)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

S, N, D = 2, 256, 16
M, L, G, K = 64, 8, 8, 4


def qkv(key=0, n=N):
    k = jax.random.PRNGKey(key)
    return (
        jax.random.normal(jax.random.fold_in(k, 0), (S, n, D)),
        jax.random.normal(jax.random.fold_in(k, 1), (S, n, D)),
        jax.random.normal(jax.random.fold_in(k, 2), (S, n, D)),
    )


def bsa(q, k, v, **kw):
    args = dict(ball_size=M, cmp_block=L, group_size=G, top_k=K)
    args.update(kw)
    return ref.ref_bsa_attention(q, k, v, **args)


def test_receptive_field_grows_with_branches():
    """Figure 2's claim: ball < ball+select < ball+select+compress.

    Measured as the number of input positions whose perturbation changes
    the output at a fixed query — via jacobian column norms."""
    q, k, v = qkv()

    def sensitivity(fn):
        # d out[0, 0, :] / d v[0, t, :] summed over channels, per t
        jac = jax.jacrev(lambda vv: fn(q, k, vv)[0, 0].sum())(v)
        return np.asarray(jnp.abs(jac[0]).sum(axis=-1) > 1e-9)

    ball_only = sensitivity(lambda q, k, v: ref.ref_ball_attention(q, k, v, M))
    full_bsa = sensitivity(lambda q, k, v: bsa(q, k, v))

    n_ball = ball_only.sum()
    n_bsa = full_bsa.sum()
    assert n_ball == M  # exactly its own ball
    assert n_bsa == N   # compression branch sees every block => global
    assert n_bsa > n_ball


def test_masked_selection_never_selects_own_ball():
    q, k, v = qkv()
    kc = ref.ref_compress_mean(k, L)
    scores = ref.ref_group_scores(q, kc, G)
    scores = ref.ref_ball_mask(scores, G, L, M)
    idx = np.asarray(ref.ref_topk_indices(scores, K))
    for s in range(S):
        for p in range(N // G):
            own_ball = (p * G) // M
            for j in idx[s, p]:
                assert (j * L) // M != own_ball


def test_unmasked_selection_prefers_similar_blocks():
    """Craft K so block 7 matches the queries; top-1 must select it."""
    q = jnp.ones((1, N, D))
    k = jnp.zeros((1, N, D)).at[:, 7 * L : 8 * L, :].set(1.0)
    kc = ref.ref_compress_mean(k, L)
    scores = ref.ref_group_scores(q, kc, G)
    idx = np.asarray(ref.ref_topk_indices(scores, 1))
    assert (idx == 7).all()


def test_gates_zero_kill_branches():
    q, k, v = qkv()
    zero = jnp.zeros((S, N, 1))
    one = jnp.ones((S, N, 1))
    out = bsa(q, k, v, gates=(zero, zero, zero))
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)
    # only-ball gate reproduces the ball branch
    out_b = bsa(q, k, v, gates=(one, zero, zero))
    np.testing.assert_allclose(
        out_b, ref.ref_ball_attention(q, k, v, M), atol=1e-5, rtol=1e-5
    )


def test_group_compress_output_is_blockwise_constant():
    """Group compression repeats each coarse output l times (eq. 15)."""
    q, k, v = qkv()
    kc = ref.ref_compress_mean(k, L)
    vc = ref.ref_compress_mean(v, L)
    qc = ref.ref_compress_mean(q, L)
    o = ref.ref_compressed_attention(qc, kc, vc)
    rep = jnp.repeat(o, L, axis=1)
    blocks = np.asarray(rep).reshape(S, N // L, L, D)
    assert (np.abs(blocks - blocks[:, :, :1, :]) < 1e-7).all()


@settings(max_examples=8, deadline=None)
@given(scale=st.floats(0.1, 4.0))
def test_bsa_permutation_equivariance_within_ball(scale):
    """Permuting tokens *within one ball* permutes outputs the same way
    (attention is permutation-equivariant; pooling blocks change, so we
    permute whole cmp-blocks to keep all three branches aligned)."""
    q, k, v = qkv()
    q, k, v = q * scale, k * scale, v * scale
    # swap two whole cmp-blocks inside ball 0 (indices 0..M)
    perm = np.arange(N)
    perm[0:L], perm[2 * L : 3 * L] = perm[2 * L : 3 * L].copy(), perm[0:L].copy()
    out = np.asarray(bsa(q, k, v))
    out_p = np.asarray(bsa(q[:, perm], k[:, perm], v[:, perm]))
    np.testing.assert_allclose(out_p, out[:, perm], atol=1e-4, rtol=1e-4)


def test_bsa_no_group_selection_matches_group_of_one():
    q, k, v = qkv()
    a = bsa(q, k, v, group_select=False)
    b = bsa(q, k, v, group_size=1)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
