"""Numpy mirror of the Rust SIMD microkernel layer's *numerics*
(rust/src/backend/simd.rs).

The Rust side's conformance gate (rust/tests/conformance.rs +
rust/tests/simd_off.rs) asserts the microkernels against their scalar
twins on the real binaries; this file re-derives the two load-bearing
numeric claims in exact float32, so they are checkable on hosts without
a Rust toolchain:

1. the polynomial ``exp_lane`` (cephes-style: clamp, magic-constant
   round-to-even, Cody-Waite ln2 split, degree-6 poly, exponent-bit
   scale) is within ~1.2e-7 relative error of true exp over the clamped
   range, is exactly 1.0 at 0, and saturates near the smallest normal
   for masked (-1e30-style) logits — which is what makes the 1e-5
   kernel twin bound safe;
2. the 8-lane + pairwise-tree ``dot``/``exp_sum`` reduction order stays
   within a reassociation-sized bound of the left-to-right scalar
   chain, including every lane-tail residue N % 8 in 1..=7.

Every constant below is a verbatim transcription of simd.rs; if a
constant drifts there, re-run this file's derivation before loosening
anything.
"""

import numpy as np

f32 = np.float32

LANES = 8

# constants mirroring rust/src/backend/simd.rs (exp_lane)
EXP_HI = f32(88.02)
EXP_LO = f32(-87.336544)
LOG2E = f32(1.442695041)
LN2_HI = f32(0.693359375)
LN2_LO = f32(-2.12194440e-4)
EXP_MAGIC = f32(12582912.0)  # 1.5 * 2^23
EXP_C = [
    f32(1.98756915e-4),
    f32(1.39819995e-3),
    f32(8.3334519e-3),
    f32(4.1665796e-2),
    f32(1.66666655e-1),
    f32(5.0000001e-1),
]


def exp_lane(x):
    """Exact-f32 mirror of simd::exp_lane (one scalar lane)."""
    x = min(max(f32(x), EXP_LO), EXP_HI)
    n = f32(f32(f32(x * LOG2E) + EXP_MAGIC) - EXP_MAGIC)
    r = f32(x - f32(n * LN2_HI))
    r = f32(r - f32(n * LN2_LO))
    p = EXP_C[0]
    for c in EXP_C[1:]:
        p = f32(f32(p * r) + c)
    p = f32(f32(p * f32(r * r)) + f32(r + f32(1.0)))
    bits = np.uint32((int(n) + 127) << 23)
    return f32(p * bits.view(f32))


def hsum8(acc):
    """Mirror of simd::hsum8: the fixed pairwise combine tree."""
    a = [f32(v) for v in acc]
    return f32(
        f32(f32(a[0] + a[1]) + f32(a[2] + a[3]))
        + f32(f32(a[4] + a[5]) + f32(a[6] + a[7]))
    )


def dot_portable(x, y):
    """Mirror of simd::dot_portable: lane accumulators, tree, tail."""
    acc = [f32(0.0)] * LANES
    n = len(x)
    lanes = n - n % LANES
    for i in range(0, lanes, LANES):
        for l in range(LANES):
            acc[l] = f32(acc[l] + f32(f32(x[i + l]) * f32(y[i + l])))
    s = hsum8(acc)
    for j in range(lanes, n):
        s = f32(s + f32(f32(x[j]) * f32(y[j])))
    return s


def dot_scalar(x, y):
    """Mirror of simd::dot_scalar: one left-to-right chain."""
    s = f32(0.0)
    for a, b in zip(x, y):
        s = f32(s + f32(f32(a) * f32(b)))
    return s


def exp_sum_portable(row, mx):
    """Mirror of simd::exp_sum_portable (in place, returns the sum)."""
    acc = [f32(0.0)] * LANES
    n = len(row)
    lanes = n - n % LANES
    out = np.array(row, dtype=f32)
    for i in range(0, lanes, LANES):
        for l in range(LANES):
            e = exp_lane(f32(out[i + l] - mx))
            out[i + l] = e
            acc[l] = f32(acc[l] + e)
    s = hsum8(acc)
    for j in range(lanes, n):
        e = exp_lane(f32(out[j] - mx))
        out[j] = e
        s = f32(s + e)
    return out, s


# ---------------------------------------------------------------------------
# exp polynomial accuracy
# ---------------------------------------------------------------------------


def test_exp_lane_relative_error_bound():
    xs = np.linspace(-87.0, 0.0, 5001).astype(f32)
    worst = 0.0
    for x in xs:
        approx = float(exp_lane(x))
        exact = float(np.exp(np.float64(x)))
        worst = max(worst, abs(approx - exact) / exact)
    assert worst < 5e-7, f"exp poly drifted: max rel err {worst}"


def test_exp_lane_anchors():
    assert float(exp_lane(0.0)) == 1.0, "exp(0) must be exactly 1"
    # masked logits (NEG_INF = -1e30 after max-subtraction) saturate at
    # the smallest normal instead of 0 — negligible in any softmax sum
    tiny = float(exp_lane(-2e30))
    assert 0.0 <= tiny < 1.3e-38
    # positive side stays finite up to the clamp
    assert np.isfinite(exp_lane(88.0))


def test_exp_lane_monotone_on_grid():
    xs = np.linspace(-30.0, 0.0, 601).astype(f32)
    vals = [float(exp_lane(x)) for x in xs]
    assert all(b >= a for a, b in zip(vals, vals[1:])), "exp poly not monotone"


# ---------------------------------------------------------------------------
# reduction reordering bounds (lane tails included)
# ---------------------------------------------------------------------------


def test_dot_tree_matches_scalar_chain_at_every_tail():
    rng = np.random.default_rng(7)
    for n in [1, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17, 33, 64, 100]:
        x = rng.standard_normal(n).astype(f32)
        y = rng.standard_normal(n).astype(f32)
        tree = float(dot_portable(x, y))
        chain = float(dot_scalar(x, y))
        l1 = float(np.sum(np.abs(x.astype(np.float64) * y.astype(np.float64))))
        tol = 8 * n * np.finfo(np.float32).eps * (l1 + 1.0)
        assert abs(tree - chain) <= tol, f"n={n}: {tree} vs {chain}"


def test_softmax_panels_match_float64_reference():
    rng = np.random.default_rng(11)
    for n in [1, 3, 7, 8, 9, 17, 40]:
        row = rng.standard_normal(n).astype(f32)
        if n >= 3:
            row[0] = f32(3e4)   # huge logit
            row[1] = f32(-1e30)  # mask value
        mx = f32(row.max())
        exps, s = exp_sum_portable(row, mx)
        got = exps / s
        ref64 = np.exp(row.astype(np.float64) - np.float64(mx))
        ref = ref64 / ref64.sum()
        assert np.all(np.isfinite(got)), f"n={n}: non-finite softmax"
        assert np.max(np.abs(got - ref)) < 1e-5, f"n={n}: softmax off"
        assert abs(got.sum() - 1.0) < 1e-5


def test_softmax_panels_handle_subnormal_rows():
    row = np.array([1e-40, -1e-40, 2e-41, 0.0, -0.0, 8.5e-39, 1e-44], dtype=f32)
    mx = f32(row.max())
    exps, s = exp_sum_portable(row, mx)
    got = exps / s
    assert np.all(np.isfinite(got))
    # subnormal logits are all ~0 apart: softmax must be ~uniform
    assert np.max(np.abs(got - 1.0 / len(row))) < 1e-6
