"""Model-level tests: shapes, pallas/ref equivalence, gradients, training."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.params import BSAConfig, TrainConfig

CFG = BSAConfig(dim=32, num_heads=2, num_blocks=2, ball_size=64, kernels="ref")
CFG_P = dataclasses.replace(CFG, kernels="pallas")
B, N = 2, 256


def data(key=0):
    k = jax.random.PRNGKey(key)
    x = jax.random.normal(k, (B, N, CFG.in_features))
    y = jax.random.normal(jax.random.fold_in(k, 1), (B, N, 1))
    return x, y


@pytest.mark.parametrize("name", ["bsa", "full", "erwin", "pointnet"])
def test_forward_shapes(name):
    x, _ = data()
    p = model.init(name, 0, CFG)
    out = model.forward(name, p, x, CFG)
    assert out.shape == (B, N, CFG.out_features)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("name", ["bsa", "full", "erwin"])
def test_pallas_matches_ref_forward(name):
    x, _ = data()
    p = model.init(name, 0, CFG)
    o_ref = model.forward(name, p, x, CFG)
    o_pal = model.forward(name, p, x, CFG_P)
    np.testing.assert_allclose(o_ref, o_pal, atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize(
    "kw",
    [
        dict(group_select=False),
        dict(group_compress=True, mlp_compress=True),
        dict(mask_own_ball=False),
        dict(mlp_compress=True),
    ],
)
def test_bsa_variants_pallas_matches_ref(kw):
    cfg_r = dataclasses.replace(CFG, num_blocks=1, **kw)
    cfg_p = dataclasses.replace(cfg_r, kernels="pallas")
    x, _ = data()
    p = model.init("bsa", 0, cfg_r)
    o_ref = model.forward("bsa", p, x, cfg_r)
    o_pal = model.forward("bsa", p, x, cfg_p)
    np.testing.assert_allclose(o_ref, o_pal, atol=5e-5, rtol=5e-5)


def test_gradients_pallas_match_ref():
    """custom_vjp (pallas fwd + oracle bwd) must equal pure-ref gradients."""
    x, y = data()
    p = model.init("bsa", 0, CFG)
    g_ref = jax.grad(lambda pp: model.loss_fn("bsa", pp, x, y, CFG))(p)
    g_pal = jax.grad(lambda pp: model.loss_fn("bsa", pp, x, y, CFG_P))(p)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref), jax.tree_util.tree_leaves(g_pal)):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-5)


def test_train_step_reduces_loss():
    """A few AdamW steps on a fixed batch must reduce the MSE (overfit)."""
    tc = TrainConfig()
    x, y = data()
    p = model.init("bsa", 0, CFG)
    m = jax.tree_util.tree_map(jnp.zeros_like, p)
    v = jax.tree_util.tree_map(jnp.zeros_like, p)
    step_fn = jax.jit(
        lambda p, m, v, s: model.train_step("bsa", p, m, v, s, 1e-3, x, y, CFG, tc)
    )
    losses = []
    for s in range(1, 16):
        p, m, v, loss = step_fn(p, m, v, float(s))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_weight_decay_only_on_matrices():
    """AdamW must not decay 1-D leaves (norm scales / biases)."""
    tc = TrainConfig(weight_decay=1.0, lr=0.1)
    p = {"w": jnp.ones((4, 4)), "s": jnp.ones((4,))}
    g = jax.tree_util.tree_map(jnp.zeros_like, p)
    m = jax.tree_util.tree_map(jnp.zeros_like, p)
    v = jax.tree_util.tree_map(jnp.zeros_like, p)
    np_, _, _ = model.adamw_update(p, g, m, v, 1.0, 0.1, tc)
    assert float(jnp.abs(np_["s"] - 1.0).max()) < 1e-7      # untouched
    assert float(np_["w"].max()) < 1.0                       # decayed


def test_rms_norm_unit_scale():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 32)) * 7.0
    out = model.rms_norm(x, jnp.ones((32,)))
    rms = jnp.sqrt(jnp.mean(jnp.square(out), axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_attn_layer_forward_kinds():
    cfg = dataclasses.replace(CFG, num_blocks=1)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, N, cfg.dim))
    p = model.attn_layer_init(jax.random.PRNGKey(1), cfg)
    for kind in ["bsa", "full", "bta"]:
        out = model.attn_layer_forward(kind, p, x, cfg)
        assert out.shape == x.shape


def test_erwin_receptive_field_is_hierarchical():
    """Erwin: a far-away perturbation must reach a point only via pooling
    (weakly), while full attention reacts strongly — sanity check on the
    baselines' inductive biases."""
    x, _ = data()
    p_e = model.init("erwin", 0, CFG)
    p_f = model.init("full", 0, CFG)
    x2 = x.at[:, -1, :].add(5.0)
    d_e = np.abs(
        np.asarray(model.forward("erwin", p_e, x2, CFG) - model.forward("erwin", p_e, x, CFG))
    )[:, 0].max()
    d_f = np.abs(
        np.asarray(model.forward("full", p_f, x2, CFG) - model.forward("full", p_f, x, CFG))
    )[:, 0].max()
    assert d_f > 0  # dense reacts
    # erwin reacts only through coarse pooling; both finite
    assert np.isfinite(d_e)


def test_config_validation_errors():
    with pytest.raises(ValueError):
        BSAConfig(dim=33, num_heads=2).validate(256)
    with pytest.raises(ValueError):
        BSAConfig(ball_size=100).validate(256)
    with pytest.raises(ValueError):
        BSAConfig(ball_size=64, cmp_block=7).validate(256)
    with pytest.raises(ValueError):
        BSAConfig(ball_size=64, top_k=1000).validate(256)
