"""AOT pipeline tests: lowering, manifest integrity, toolchain contracts."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.params import BSAConfig


def test_hlo_text_has_no_unparseable_ops():
    """The lowered text must avoid HLO features the 0.5.1 toolchain
    rejects: the `topk` instruction and 64-bit-id serialized protos
    (text is the format; topk is the one op we had to design around)."""
    cfg = BSAConfig(dim=32, num_heads=2, num_blocks=1, ball_size=64, kernels="ref")
    x = jax.ShapeDtypeStruct((1, 256, 6), jnp.float32)
    params = jax.eval_shape(lambda s: model.init("bsa", s, cfg), jnp.int32(0))
    flat, tree = jax.tree_util.tree_flatten(params)

    def fwd(*args):
        p = jax.tree_util.tree_unflatten(tree, args[: len(flat)])
        return (model.forward("bsa", p, args[len(flat)], cfg),)

    text = aot.to_hlo_text(jax.jit(fwd).lower(*flat, x))
    assert "HloModule" in text
    assert " topk(" not in text, "lax.top_k leaked into the artifact"


def test_unused_params_would_be_dce_hazard():
    """Guard for the gating bug: every lowered entry parameter of the full
    and erwin fwd graphs must survive into the HLO signature (no DCE'd
    params => manifest matches the executable)."""
    for name in ["full", "erwin"]:
        cfg = BSAConfig(dim=32, num_heads=2, num_blocks=1, ball_size=64, kernels="ref")
        x = jax.ShapeDtypeStruct((1, 256, 6), jnp.float32)
        params = jax.eval_shape(lambda s: model.init(name, s, cfg), jnp.int32(0))
        flat, tree = jax.tree_util.tree_flatten(params)

        def fwd(*args):
            p = jax.tree_util.tree_unflatten(tree, args[: len(flat)])
            return (model.forward(name, p, args[len(flat)], cfg),)

        text = aot.to_hlo_text(jax.jit(fwd).lower(*flat, x))
        entry = text.splitlines()[0]
        # count f32 tensors in the entry layout == flat params + x
        n_inputs = entry.split("->")[0].count("f32[")
        assert n_inputs == len(flat) + 1, f"{name}: {n_inputs} != {len(flat) + 1}"


def test_manifest_names_and_shapes_align():
    mf = aot.ManifestWriter()
    cfg = BSAConfig(dim=32, num_heads=2, num_blocks=1, ball_size=64)
    ins = [jax.ShapeDtypeStruct((2, 3), jnp.float32)]
    outs = [jax.ShapeDtypeStruct((), jnp.float32)]
    mf.graph("g", "g.hlo.txt", "fwd", "t", cfg, 256, 1, 1, ins, outs,
             in_names=["w"], out_names=["loss"])
    text = "\n".join(mf.lines)
    assert "[graph g]" in text
    assert "input 0 f32 2,3 w" in text
    assert "output 0 f32 scalar loss" in text


def test_spec_tags_are_unique_across_suites():
    seen = {}
    for suite in ["core", "bench"]:
        for spec in aot.suite_specs(suite):
            key = spec.tag
            if key in seen:
                assert seen[key] == spec, f"tag collision: {key}"
            seen[key] = spec


def test_spec_cfg_validates():
    for suite in ["core", "bench"]:
        for spec in aot.suite_specs(suite):
            spec.cfg().validate(spec.n)


def test_topk_cascade_matches_lax_topk():
    """Our argmax cascade must agree with jax.lax.top_k on distinct scores."""
    from compile.kernels import ref

    key = jax.random.PRNGKey(0)
    scores = jax.random.normal(key, (3, 16, 32))
    ours = np.asarray(ref.ref_topk_indices(scores, 4))
    _, theirs = jax.lax.top_k(scores, 4)
    theirs = np.sort(np.asarray(theirs), axis=-1)
    np.testing.assert_array_equal(ours, theirs)


def test_gated_vs_ungated_param_sets():
    cfg = BSAConfig(dim=32, num_heads=2, num_blocks=1, ball_size=64)
    bsa_names = aot._flat_names(jax.eval_shape(lambda s: model.init("bsa", s, cfg), jnp.int32(0)))
    full_names = aot._flat_names(jax.eval_shape(lambda s: model.init("full", s, cfg), jnp.int32(0)))
    assert any("wg" in n for n in bsa_names)
    assert not any("wg" in n for n in full_names)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.txt")),
    reason="artifacts not built",
)
def test_built_manifest_parses_and_files_exist():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    graphs = 0
    with open(os.path.join(root, "manifest.txt")) as f:
        for line in f:
            if line.startswith("file "):
                fname = line.split()[1]
                assert os.path.exists(os.path.join(root, fname)), fname
                graphs += 1
    assert graphs > 5
