#!/usr/bin/env bash
# One-command gate: formatting, tier-1 build+tests (debug AND release —
# the parallel kernels must pass with the optimizer on, where
# race-adjacent bugs actually surface), lints, rustdoc with
# warnings-as-errors (README / FORMATS.md cross-references must not
# rot), and the perf artifacts (BENCH_serve.json + BENCH_native.json) in
# smoke mode. CI (.github/workflows/ci.yml) and pre-PR runs use this so
# the correctness gate and the perf trajectory can't drift apart; the
# toolchain is pinned by rust-toolchain.toml so local and CI runs agree.
#
#   scripts/check.sh                # full gate
#   scripts/check.sh --quick        # fmt + build + conformance + poll-core
#                                   # server tests (native_tcp_*) + shard
#                                   # chaos suite + 2-worker loadgen smoke
#   BENCH_REPS=5 scripts/check.sh   # heavier perf sampling
#
# After the benches refresh the artifacts, scripts/benchdiff.py prints a
# per-metric delta table against the committed baselines (informational;
# pass --fail-over to benchdiff for a hard threshold). The full gate
# additionally guards the native perf trajectory: if a committed
# BENCH_native.json has a numeric single-thread throughput baseline
# (threads_sweep, threads=1, fwd_per_s) and both the baseline and the
# fresh run sampled with reps >= 3 (single-sample smoke runs are noise),
# the fresh run must stay within 10% of the baseline or the gate fails.
# The full gate also smoke-tests the tracing subsystem end to end (a
# traced serve answered by `bsa stats`, plus Chrome-trace validation of
# the --trace-out file) and fails if the bench-measured spans-on
# overhead (BENCH_native.json trace_overhead.overhead_pct) exceeds 3%.
set -euo pipefail
cd "$(dirname "$0")/.."

QUICK=0
for arg in "$@"; do
  case "$arg" in
    --quick) QUICK=1 ;;
    *) echo "check.sh: unknown flag ${arg} (supported: --quick)" >&2; exit 2 ;;
  esac
done

REPS="${BENCH_REPS:-1}"

if [[ "$QUICK" == 1 ]]; then
  (
    cd rust
    echo "== cargo fmt --check"
    cargo fmt --check
    echo "== cargo build --release"
    cargo build --release
    echo "== cargo test -q --release --test conformance"
    cargo test -q --release --test conformance
    echo "== cargo test -q --release --test simd_off (BSA_NATIVE_SIMD=off bitwise gate)"
    cargo test -q --release --test simd_off
    echo "== cargo test -q --release --test grad_conformance (backward kernels: FD oracles + bitwise twins)"
    cargo test -q --release --test grad_conformance
    echo "== cargo test -q --release --test integration native_tcp (poll-core server gate: pipelining, shedding, 256 idle conns)"
    cargo test -q --release --test integration native_tcp
    echo "== cargo test -q --release --test shard_chaos (shard tier gate: affinity, kills, shed storms, restart detection)"
    cargo test -q --release --test shard_chaos
  )

  # Shard-tier smoke: a real front door spawning 2 worker processes,
  # hit by a 2-second open-loop loadgen run. Runs from a temp dir so
  # the quick tier never rewrites the committed BENCH_serve.json.
  echo "== shard smoke (bsa shard, 2 spawned workers + bsa loadgen --quick)"
  REPO_ROOT="$(pwd)"
  SHARD_ADDR="127.0.0.1:17897"
  "$REPO_ROOT/rust/target/release/bsa" shard --backend native --task syn --n 256 \
    --addr "$SHARD_ADDR" --workers 2 --worker-base-port 17898 &
  SHARD_PID=$!
  sleep 2
  LOADGEN_OUT="$(cd "$(mktemp -d)" && "$REPO_ROOT/rust/target/release/bsa" loadgen "$SHARD_ADDR" \
    --quick --task syn --points 200)" || {
    echo "check.sh: loadgen failed against the shard front door" >&2
    kill "$SHARD_PID" 2>/dev/null || true
    exit 1
  }
  if ! grep -q "shed_rate" <<<"$LOADGEN_OUT"; then
    echo "check.sh: loadgen output is missing its report:" >&2
    echo "$LOADGEN_OUT" >&2
    kill "$SHARD_PID" 2>/dev/null || true
    exit 1
  fi
  kill -INT "$SHARD_PID"
  wait "$SHARD_PID" || true

  # Numpy gradient mirror: the same flash-backward / RMSNorm / SwiGLU /
  # Adam identities the Rust kernels implement, checked against
  # finite differences (and jax.grad when jax is importable) in float64.
  if command -v python3 >/dev/null 2>&1 && python3 -c 'import numpy, pytest' 2>/dev/null; then
    echo "== python grad mirror (python/tests/test_grad_mirror.py)"
    python3 -m pytest -q python/tests/test_grad_mirror.py
  else
    echo "check.sh: python3+numpy+pytest unavailable; grad mirror skipped"
  fi

  echo "check.sh --quick: fmt + build + kernel conformance + grad gates + poll-core + shard tier gates passed"
  exit 0
fi

# Stash the committed perf baselines before the benches overwrite them
# (benchdiff + the regression gate both need the pre-run numbers).
BASELINE_NATIVE=""
BASELINE_SERVE=""
if [[ -f BENCH_native.json ]]; then
  BASELINE_NATIVE="$(mktemp)"
  cp BENCH_native.json "$BASELINE_NATIVE"
fi
if [[ -f BENCH_serve.json ]]; then
  BASELINE_SERVE="$(mktemp)"
  cp BENCH_serve.json "$BASELINE_SERVE"
fi
trap '[[ -z "${BASELINE_NATIVE}" ]] || rm -f "${BASELINE_NATIVE}"; [[ -z "${BASELINE_SERVE}" ]] || rm -f "${BASELINE_SERVE}"' EXIT

(
  cd rust
  echo "== cargo fmt --check"
  cargo fmt --check
  echo "== cargo build --release"
  cargo build --release
  echo "== cargo test -q"
  cargo test -q
  echo "== cargo test -q --release (parallel kernels with the optimizer on)"
  cargo test -q --release
  echo "== cargo clippy --all-targets -- -D warnings"
  cargo clippy --all-targets -- -D warnings
  echo "== cargo doc --no-deps (rustdoc warnings are errors: docs must not rot)"
  RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
  echo "== serve_hot_path bench (smoke, --reps ${REPS})"
  cargo bench --bench paper -- serve_hot_path --reps "${REPS}"
  echo "== bsa_native bench (smoke, --reps ${REPS}; artifact-free e2e + threads/simd sweeps; n_sweep capped at 32k)"
  cargo bench --bench paper -- bsa_native --reps "${REPS}" --quick
)

# Trace-layer smoke: a short traced native serve must answer `bsa stats`
# with per-stage span histograms, and --trace-out must produce a
# Perfetto-loadable Chrome trace on shutdown. trace.json is left in the
# repo root so CI can upload it as a build artifact.
echo "== trace smoke (serve --trace spans -> bsa stats -> chrome trace)"
TRACE_ADDR="127.0.0.1:17891"
rm -f trace.json
rust/target/release/bsa serve --backend native --task syn --n 256 \
  --trace spans --trace-out trace.json --addr "$TRACE_ADDR" &
SERVE_PID=$!
sleep 2
STATS_OUT="$(rust/target/release/bsa stats "$TRACE_ADDR" --probe --task syn --points 200)" || {
  echo "check.sh: bsa stats failed against the traced server" >&2
  kill "$SERVE_PID" 2>/dev/null || true
  exit 1
}
for span in serve.decode router.preprocess forward.layer.ball_attention; do
  if ! grep -q "$span" <<<"$STATS_OUT"; then
    echo "check.sh: traced stats output is missing the ${span} span:" >&2
    echo "$STATS_OUT" >&2
    kill "$SERVE_PID" 2>/dev/null || true
    exit 1
  fi
done
kill -INT "$SERVE_PID"
wait "$SERVE_PID" || true
python3 - trace.json <<'PYEOF'
import json, sys
with open(sys.argv[1]) as f:
    doc = json.load(f)
events = doc.get("traceEvents")
assert isinstance(events, list) and events, "trace.json has no traceEvents"
assert all(e.get("ph") == "X" for e in events), "expected complete ('X') events"
names = {e.get("name") for e in events}
assert any(n and n.startswith("forward") for n in names), f"no forward spans in {sorted(names)[:10]}"
print(f"check.sh: chrome trace ok ({len(events)} events, {len(names)} distinct spans)")
PYEOF

# Native-training smoke: 2 optimizer steps end to end through the CLI
# (`bsa train --backend native` — no artifacts, no Python toolchain),
# writing a v3 checkpoint that `bsa eval --backend native` must then
# resume. Guards the train -> checkpoint -> eval round-trip documented
# in docs/TRAINING.md.
echo "== native train smoke (bsa train --backend native, 2 steps -> v3 checkpoint -> bsa eval)"
TRAIN_DIR="$(mktemp -d)"
TRAIN_OUT="$(rust/target/release/bsa train --backend native --task syn --n 256 \
  --steps 2 --checkpoint "$TRAIN_DIR/smoke.bsackpt")" || {
  echo "check.sh: bsa train --backend native failed:" >&2
  echo "$TRAIN_OUT" >&2
  rm -rf "$TRAIN_DIR"
  exit 1
}
if ! grep -q "checkpoint saved" <<<"$TRAIN_OUT" || [[ ! -s "$TRAIN_DIR/smoke.bsackpt" ]]; then
  echo "check.sh: native train smoke did not write its checkpoint:" >&2
  echo "$TRAIN_OUT" >&2
  rm -rf "$TRAIN_DIR"
  exit 1
fi
rust/target/release/bsa eval --backend native --task syn --n 256 \
  --checkpoint "$TRAIN_DIR/smoke.bsackpt" >/dev/null || {
  echo "check.sh: bsa eval --backend native could not resume the v3 checkpoint" >&2
  rm -rf "$TRAIN_DIR"
  exit 1
}
rm -rf "$TRAIN_DIR"
echo "check.sh: native train -> v3 checkpoint -> eval round-trip ok"

# rebar-style per-metric deltas vs the committed baselines
# (informational here; CI can add --fail-over for a hard threshold)
if command -v python3 >/dev/null 2>&1; then
  if [[ -n "${BASELINE_NATIVE}" ]]; then
    python3 scripts/benchdiff.py "$BASELINE_NATIVE" BENCH_native.json --label native || true
  fi
  if [[ -n "${BASELINE_SERVE}" ]]; then
    python3 scripts/benchdiff.py "$BASELINE_SERVE" BENCH_serve.json --label serve || true
  fi
fi

# Single-thread throughput regression gate (>10% vs the committed
# baseline). Arms only when BOTH runs sampled with reps >= 3 — a
# single-sample fwd_per_s (the default smoke reps=1) is scheduling
# noise and must neither fail the gate nor ratchet a lucky baseline.
if [[ -n "${BASELINE_NATIVE}" ]] && command -v python3 >/dev/null 2>&1; then
  python3 - "$BASELINE_NATIVE" BENCH_native.json <<'PYEOF'
import json, sys

MIN_REPS = 3

def sweep_point(path):
    """(fwd_per_s at threads=1, reps) or (None, reps) when absent."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except Exception:
        return None, 0
    reps = doc.get("reps") if isinstance(doc.get("reps"), int) else 0
    for row in doc.get("threads_sweep") or []:
        fps = row.get("fwd_per_s")
        if row.get("threads") == 1 and isinstance(fps, (int, float)) and not isinstance(fps, bool):
            return float(fps), reps
    return None, reps

def trace_overhead(path):
    """(overhead_pct, reps) from the trace_overhead record, or (None, reps)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except Exception:
        return None, 0
    reps = doc.get("reps") if isinstance(doc.get("reps"), int) else 0
    rec = doc.get("trace_overhead") or {}
    pct = rec.get("overhead_pct")
    if isinstance(pct, (int, float)) and not isinstance(pct, bool):
        return float(pct), reps
    return None, reps

# Spans-on tracing overhead gate: the fresh run's measured overhead must
# stay under 3% (single-sample smoke runs are too noisy to arm it).
MAX_TRACE_OVERHEAD_PCT = 3.0
pct, pct_reps = trace_overhead(sys.argv[2])
if pct is None:
    print("check.sh: fresh BENCH_native.json has no trace_overhead record; overhead gate skipped")
elif pct_reps < MIN_REPS:
    print(f"check.sh: trace overhead gate skipped — needs reps >= {MIN_REPS} "
          f"(current reps={pct_reps}); measured {pct:+.2f}% informationally")
elif pct > MAX_TRACE_OVERHEAD_PCT:
    sys.exit(f"check.sh: span-tracing overhead {pct:.2f}% exceeds "
             f"{MAX_TRACE_OVERHEAD_PCT:.1f}% (spans must stay near-free)")
else:
    print(f"check.sh: span-tracing overhead ok ({pct:+.2f}% <= {MAX_TRACE_OVERHEAD_PCT:.1f}%)")

base, base_reps = sweep_point(sys.argv[1])
cur, cur_reps = sweep_point(sys.argv[2])
if base is None:
    print("check.sh: committed BENCH_native.json has no numeric single-thread "
          "baseline yet; regression gate skipped (commit a BENCH_REPS>=3 run to arm it)")
elif cur is None:
    sys.exit("check.sh: fresh BENCH_native.json lost its threads_sweep — bench broken?")
elif base_reps < MIN_REPS or cur_reps < MIN_REPS:
    print(f"check.sh: regression gate skipped — needs reps >= {MIN_REPS} on both sides "
          f"(baseline reps={base_reps}, current reps={cur_reps}; rerun with BENCH_REPS>=3)")
elif cur < 0.9 * base:
    sys.exit(f"check.sh: single-thread native throughput regressed >10%: "
             f"{base:.3f} -> {cur:.3f} fwd/s")
else:
    print(f"check.sh: single-thread native throughput ok: {base:.3f} -> {cur:.3f} fwd/s")
PYEOF
elif [[ -n "${BASELINE_NATIVE}" ]]; then
  echo "check.sh: WARNING — baseline present but python3 unavailable; regression gate NOT run"
else
  echo "check.sh: no committed BENCH_native.json baseline; regression gate skipped"
fi

echo "check.sh: all gates passed; BENCH_serve.json + BENCH_native.json refreshed"
