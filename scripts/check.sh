#!/usr/bin/env bash
# One-command gate: tier-1 build+tests, lints, and the serving perf
# artifact (BENCH_serve.json) in smoke mode. CI and pre-PR runs use this
# so the correctness gate and the perf trajectory can't drift apart.
#
#   scripts/check.sh            # full gate
#   BENCH_REPS=5 scripts/check.sh   # heavier perf sampling
set -euo pipefail
cd "$(dirname "$0")/.."

REPS="${BENCH_REPS:-1}"

(
  cd rust
  echo "== cargo build --release"
  cargo build --release
  echo "== cargo test -q"
  cargo test -q
  echo "== cargo clippy --all-targets -- -D warnings"
  cargo clippy --all-targets -- -D warnings
  echo "== serve_hot_path bench (smoke, --reps ${REPS})"
  cargo bench --bench paper -- serve_hot_path --reps "${REPS}"
  echo "== bsa_native bench (smoke, --reps ${REPS}; artifact-free e2e)"
  cargo bench --bench paper -- bsa_native --reps "${REPS}"
)

echo "check.sh: all gates passed; BENCH_serve.json + BENCH_native.json refreshed"
