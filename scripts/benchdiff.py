#!/usr/bin/env python3
"""Diff two BENCH_*.json perf artifacts into a per-section delta table.

rebar-style cross-run comparison for the repo's machine-readable perf
trajectory (BENCH_native.json / BENCH_serve.json):

    scripts/benchdiff.py OLD.json NEW.json
    scripts/benchdiff.py OLD.json NEW.json --fail-over 10
    scripts/benchdiff.py OLD.json NEW.json --section threads_sweep

Every numeric measurement leaf is flattened to a dotted path (list
entries are keyed by their "name"/"threads"/"n" field when present, by
index otherwise), matched across the two documents, and reported with
its percent delta and a direction-aware verdict:

    lower-is-better   keys ending in _us / _ms / _mb (peak RSS,
                      train_step.grad_peak_rss_mb), p50/p95 latencies,
                      misses, overhead_pct (tracing overhead)
    higher-is-better  keys ending in per_s (fwd_per_s,
                      train_step.steps_per_s), speedup, hits, saved_us

Keys that are run descriptors rather than measurements (reps, threads,
n, calls, requests, ...) are ignored. A leaf that is null on either
side (structure-only placeholders) is skipped with a note, so the tool
is safe against the committed pre-toolchain baselines.

``--fail-over PCT`` exits 2 if any direction-known metric regressed by
more than PCT percent — the CI-facing mode. Without it the tool always
exits 0 (the informational mode scripts/check.sh runs after refreshing
the artifacts).
"""

from __future__ import annotations

import argparse
import json
import sys

# run descriptors, not measurements
SKIP_KEYS = {
    "reps", "threads", "n", "calls", "requests", "geometries", "n_points",
    "target_len", "units", "rows", "width", "batch", "dim", "heads",
    "blocks", "ball", "available", "count", "steps",
}

HIGHER_SUFFIXES = ("per_s", "speedup", "speedup_vs_1t", "hits", "saved_us", "hit_ratio")
LOWER_SUFFIXES = ("_us", "_ms", "_mb", "misses", "overhead_pct", "shed_rate")


def direction(path: str) -> str | None:
    """'higher' / 'lower' is-better for a dotted metric path, else None."""
    leaf = path.rsplit(".", 1)[-1]
    for suf in HIGHER_SUFFIXES:
        if leaf == suf or leaf.endswith(suf):
            return "higher"
    for suf in LOWER_SUFFIXES:
        if leaf == suf or leaf.endswith(suf):
            return "lower"
    return None


def _entry_key(entry: dict, index: int) -> str:
    """Stable key for a list element: its name/threads/n field, else index."""
    for field in ("name", "threads", "n", "label"):
        if field in entry and not isinstance(entry[field], (dict, list)):
            return f"{field}={entry[field]}"
    return str(index)


def flatten(doc, prefix: str = "") -> dict:
    """Dotted path -> numeric-or-None for every measurement leaf."""
    out: dict = {}
    if isinstance(doc, dict):
        for key, val in doc.items():
            path = f"{prefix}.{key}" if prefix else str(key)
            if isinstance(val, (dict, list)):
                out.update(flatten(val, path))
            elif key in SKIP_KEYS or isinstance(val, (str, bool)):
                continue
            else:  # number or null
                out[path] = val
    elif isinstance(doc, list):
        for i, val in enumerate(doc):
            if isinstance(val, dict):
                out.update(flatten(val, f"{prefix}[{_entry_key(val, i)}]"))
            elif isinstance(val, (int, float)) and not isinstance(val, bool):
                out[f"{prefix}[{i}]"] = val
    return out


def diff(old_doc, new_doc, section: str | None = None) -> tuple[list, int]:
    """Matched-metric rows plus the count of skipped (null/unmatched) leaves.

    Each row is (path, old, new, delta_pct, verdict) where verdict is
    'better' / 'worse' / '~' (within noise or direction-unknown).
    """
    old_flat = flatten(old_doc)
    new_flat = flatten(new_doc)
    rows = []
    skipped = 0
    for path in sorted(set(old_flat) | set(new_flat)):
        if section and not path.startswith(section):
            continue
        old = old_flat.get(path)
        new = new_flat.get(path)
        if old is None or new is None:
            skipped += 1
            continue
        if old == 0:
            delta = 0.0 if new == 0 else float("inf")
        else:
            delta = (new - old) / abs(old) * 100.0
        verdict = "~"
        d = direction(path)
        if d and abs(delta) >= 1.0:
            improved = (delta > 0) == (d == "higher")
            verdict = "better" if improved else "worse"
        rows.append((path, old, new, delta, verdict))
    return rows, skipped


def regressions(rows, fail_over: float) -> list:
    """Rows whose direction-aware delta is worse by more than fail_over %."""
    out = []
    for path, old, new, delta, _ in rows:
        d = direction(path)
        if d is None:
            continue
        worse = -delta if d == "higher" else delta
        if worse > fail_over:
            out.append((path, old, new, delta))
    return out


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.3f}" if abs(v) < 1000 else f"{v:.1f}"
    return str(v)


def render(rows, skipped: int) -> str:
    if not rows:
        return f"benchdiff: no comparable numeric metrics ({skipped} null/unmatched leaves skipped)\n"
    widths = [
        max(len("metric"), *(len(r[0]) for r in rows)),
        max(len("old"), *(len(_fmt(r[1])) for r in rows)),
        max(len("new"), *(len(_fmt(r[2])) for r in rows)),
    ]
    lines = [
        f"{'metric'.ljust(widths[0])}  {'old'.rjust(widths[1])}  "
        f"{'new'.rjust(widths[2])}  {'delta%':>8}  verdict"
    ]
    lines.append("-" * len(lines[0]))
    for path, old, new, delta, verdict in rows:
        lines.append(
            f"{path.ljust(widths[0])}  {_fmt(old).rjust(widths[1])}  "
            f"{_fmt(new).rjust(widths[2])}  {delta:>+8.1f}  {verdict}"
        )
    if skipped:
        lines.append(f"({skipped} null/unmatched leaves skipped)")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", help="baseline BENCH_*.json")
    ap.add_argument("new", help="fresh BENCH_*.json")
    ap.add_argument(
        "--fail-over",
        type=float,
        metavar="PCT",
        help="exit 2 if any metric regressed by more than PCT percent",
    )
    ap.add_argument(
        "--section", help="only compare dotted paths under this prefix"
    )
    ap.add_argument(
        "--label", default="", help="tag printed above the table (e.g. native)"
    )
    args = ap.parse_args(argv)

    docs = []
    for path in (args.old, args.new):
        try:
            with open(path) as fh:
                docs.append(json.load(fh))
        except (OSError, json.JSONDecodeError) as e:
            print(f"benchdiff: cannot read {path}: {e}", file=sys.stderr)
            return 1

    rows, skipped = diff(docs[0], docs[1], args.section)
    if args.label:
        print(f"== benchdiff [{args.label}]: {args.old} -> {args.new}")
    print(render(rows, skipped), end="")

    if args.fail_over is not None:
        regs = regressions(rows, args.fail_over)
        if regs:
            print(
                f"benchdiff: {len(regs)} metric(s) regressed beyond "
                f"{args.fail_over:.1f}%:",
                file=sys.stderr,
            )
            for path, old, new, delta in regs:
                print(
                    f"  {path}: {_fmt(old)} -> {_fmt(new)} ({delta:+.1f}%)",
                    file=sys.stderr,
                )
            return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
