//! Elasticity task demo (paper Table 2's setting): trains BSA on the
//! Kirsch plate-with-hole stress fields at the benchmark's native scale
//! (972 nodes -> padded to 1024 by the ball tree).
//!
//!   make artifacts-bench && cargo run --release --example elasticity -- [steps]
//!
//! Needs the bench artifact suite (bsa_ela_n1024_b2). Falls back to a
//! dataset-only inspection when the artifact is absent.

use std::sync::Arc;

use bsa::config::TrainConfig;
use bsa::coordinator::Trainer;
use bsa::data::generator_for;
use bsa::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(200);

    // Inspect the substrate: the analytic stress field.
    let gen = generator_for("ela", 0)?;
    let cell = gen.generate(0, 972);
    println!(
        "elasticity sample: {} nodes, von Mises stress range [{:.3}, {:.3}] (SCF {:.2})",
        cell.coords.rows(),
        cell.target.min(),
        cell.target.max(),
        cell.target.max() // far field is 1.0 by construction
    );

    let engine = Arc::new(Engine::new(&Engine::default_dir())?);
    // the elasticity training graph is part of the bench suite (lowered
    // with the XLA-fused reference kernels — see aot.py)
    let tag = "bsa_ela_n1024_b2_ref";
    if engine.manifest.get(&format!("train_{tag}")).is_err() {
        println!("bench artifacts not built (run `make artifacts-bench`); dataset demo only.");
        return Ok(());
    }

    let tc = TrainConfig {
        task: "ela".into(),
        steps,
        train_samples: 96,
        test_samples: 24,
        log_every: 10,
        warmup: steps / 20 + 1,
        ..Default::default()
    };
    let mut trainer = Trainer::new(engine, tag, tc)?;
    let mse0 = trainer.evaluate()?;
    trainer.run(|e| {
        println!("step {:>5}  loss {:.5}  {:.0} ms/step", e.step, e.loss, e.ms_per_step);
    })?;
    let mse = trainer.evaluate()?;
    // Table 2 reports RMSE x 10^2 on normalized stress
    println!("---");
    println!(
        "test RMSE x100: {:.2} (random) -> {:.2} (trained)",
        mse0.sqrt() * 100.0,
        mse.sqrt() * 100.0
    );
    anyhow::ensure!(mse < mse0, "training must improve");
    Ok(())
}
