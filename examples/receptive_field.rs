//! Figure 2 reproduction: receptive-field growth per BSA component.
//!
//! For a procedurally generated car and a chosen query point, renders the
//! set of input positions each attention branch can reach:
//!
//!   * ball attention   — exactly the query's own ball (local),
//!   * + selection      — plus the top-k* compressed blocks (own-ball
//!                        blocks masked, pushing selection *outward*),
//!   * + compression    — every block at coarse resolution (global).
//!
//!   cargo run --release --example receptive_field
//!
//! Writes receptive_field_{ball,select,compress}.ppm + prints the counts.

use bsa::balltree::BallTree;
use bsa::data::generator_for;
use bsa::rfield::{receptive_field, RFieldParams};
use bsa::viz::{diverging, project_xz, Image};

const N: usize = 4096;

fn main() -> anyhow::Result<()> {
    let gen = generator_for("air", 11)?;
    let car = gen.generate(0, 3584);
    let tree = BallTree::build(&car.coords, N, 11);
    let feats = tree.permute_features(&car.features);

    let params = RFieldParams::default(); // paper Table 4 values
    let query_pos = N / 2;
    let rf = receptive_field(&feats, query_pos, params, 42);
    let (nb, ns, nc) = rf.counts();

    println!("receptive field at query position {query_pos} (ball {}):", rf.query_ball);
    println!("  ball attention         : {nb:>5} / {N} positions");
    println!("  + selection (k*={})     : {ns:>5} / {N} positions", params.top_k);
    println!("  + compression (coarse) : {nc:>5} / {N} positions");
    println!(
        "  selected blocks {:?} (own ball {} masked out)",
        rf.selected_blocks, rf.query_ball
    );

    let px = project_xz(&tree.coords, 640, 360);
    for (name, reach, coarse) in [
        ("receptive_field_ball.ppm", &rf.ball, false),
        ("receptive_field_select.ppm", &rf.select, false),
        ("receptive_field_compress.ppm", &rf.compress, true),
    ] {
        let mut img = Image::new(640, 360);
        for i in 0..N {
            if !tree.real[i] {
                continue;
            }
            let (x, y) = px[i];
            let rgb = if i == query_pos {
                [255, 255, 60] // the query
            } else if reach[i] {
                if coarse { diverging(0.75) } else { diverging(0.95) }
            } else {
                [70, 70, 78]
            };
            img.splat(x, y, if i == query_pos { 4 } else { 1 }, rgb);
        }
        img.save_ppm(std::path::Path::new(name))?;
        println!("wrote {name}");
    }
    Ok(())
}
