//! Quickstart: load the compiled BSA model, predict airflow pressure on a
//! procedurally generated car, print field statistics.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! Walks the full request path: synthetic geometry -> ball-tree
//! permutation -> compiled HLO forward pass -> inverse permutation.

use std::path::Path;
use std::sync::Arc;

use bsa::balltree::BallTree;
use bsa::config::ServeConfig;
use bsa::coordinator::Router;
use bsa::data::generator_for;
use bsa::runtime::{literal_to_tensor, scalar_i32, Engine};
use bsa::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let artifacts = Engine::default_dir();
    let engine = Arc::new(Engine::new(&artifacts)?);
    println!("PJRT platform: {}", engine.platform());

    // The paper's ShapeNet setting: ~3586 surface points, padded to 4096
    // by the ball tree. The fwd graph was AOT-lowered by `make artifacts`.
    // Prefer the XLA-fused artifact for serving speed when the bench suite
    // is built; the Pallas-interpret graph (same numerics, pytest-proven)
    // is the fallback from the core suite.
    let tag = if engine.manifest.get("fwd_bsa_air_n4096_b1_ref").is_ok() {
        "bsa_air_n4096_b1_ref"
    } else {
        "bsa_air_n4096_b1"
    };
    let gen = generator_for("air", 7)?;
    let car = gen.generate(0, 3584);
    println!(
        "generated car: {} surface points, pressure field std {:.3}",
        car.coords.rows(),
        car.target.std()
    );

    // Ball-tree diagnostics: the geometric regularization BSA relies on.
    let tree = BallTree::build(&car.coords, 4096, 7);
    println!(
        "ball tree: {} balls of 256, mean radius {:.3} (cloud radius {:.3})",
        tree.num_balls(256),
        tree.mean_radius(256),
        tree.mean_radius(4096),
    );

    // Parameters: random init (swap in a checkpoint from `bsa train` for
    // trained weights). Param shapes are N-independent, so the n1024
    // training init serves the n4096 graph.
    let init = engine.load("init_bsa_air_n1024_b2")?;
    let params: Vec<Tensor> = init
        .run(&[scalar_i32(0)])?
        .iter()
        .map(literal_to_tensor)
        .collect::<Result<_, _>>()?;
    if let Some(ckpt) = std::env::args().nth(1) {
        println!("loading checkpoint {ckpt}");
        let ck = bsa::coordinator::checkpoint::Checkpoint::load(Path::new(&ckpt))?;
        let n = params.len();
        let loaded: Vec<Tensor> = ck.arrays.into_iter().take(n).map(|(_, t)| t).collect();
        return run_inference(engine, tag, loaded, car);
    }
    run_inference(engine, tag, params, car)
}

fn run_inference(
    engine: Arc<Engine>,
    tag: &str,
    params: Vec<Tensor>,
    car: bsa::data::Sample,
) -> anyhow::Result<()> {
    let router = Router::start_pjrt(
        engine,
        &format!("fwd_{tag}"),
        params,
        ServeConfig::default(),
    )?;

    let t0 = std::time::Instant::now();
    let pred = router.infer(car.coords.clone(), car.features.clone())?;
    let dt = t0.elapsed();

    println!(
        "predicted pressure for {} points in {:.1} ms",
        pred.rows(),
        dt.as_secs_f64() * 1e3
    );
    println!(
        "prediction stats: mean {:.4} std {:.4} min {:.4} max {:.4}",
        pred.mean(),
        pred.std(),
        pred.min(),
        pred.max()
    );
    println!("router served={} p50={:.1}us", router.stats().served, router.latency_us(50.0));
    Ok(())
}
