//! End-to-end training driver (the repo's E2E validation run).
//!
//! Trains the paper's BSA transformer on the procedural airflow-pressure
//! task through the full three-layer stack — rust data/ball-tree/loop,
//! compiled JAX train-step (AdamW fused), Pallas attention kernels — and
//! logs the loss curve + held-out MSE. Results recorded in EXPERIMENTS.md.
//!
//!   make artifacts && cargo run --release --example train_airflow -- [steps]
//!
//! Writes `train_airflow_loss.csv` and `train_airflow.bsackpt`.

use std::io::Write;
use std::path::Path;
use std::sync::Arc;

use bsa::config::TrainConfig;
use bsa::coordinator::Trainer;
use bsa::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(300);

    let engine = Arc::new(Engine::new(&Engine::default_dir())?);
    println!("PJRT platform: {}", engine.platform());

    let tc = TrainConfig {
        task: "air".into(),
        steps,
        batch: 2,
        train_samples: 96,
        test_samples: 24,
        log_every: 10,
        warmup: steps / 20 + 1,
        ..Default::default()
    };
    println!(
        "training bsa_air_n1024_b2: {} steps, lr {} (cosine), wd {}, {}+{} samples",
        tc.steps, tc.lr, tc.weight_decay, tc.train_samples, tc.test_samples
    );

    let mut trainer = Trainer::new(engine, "bsa_air_n1024_b2", tc)?;
    let mse0 = trainer.evaluate()?;
    println!("random-init test MSE: {mse0:.4}");

    let t0 = std::time::Instant::now();
    trainer.run(|e| {
        println!(
            "step {:>5}  loss {:.5}  lr {:.2e}  {:.0} ms/step",
            e.step, e.loss, e.lr, e.ms_per_step
        );
    })?;
    let wall = t0.elapsed().as_secs_f64();

    let mse = trainer.evaluate()?;
    let stats = trainer.step_time_stats();
    println!("---");
    println!("trained {} steps in {:.1}s ({:.0} ms/step mean)", trainer.step, wall, stats.mean());
    println!("test MSE: {mse0:.4} (random) -> {mse:.4} (trained)  [x100: {:.2}]", mse * 100.0);

    // loss curve CSV for EXPERIMENTS.md
    let mut csv = String::from("step,loss,lr,ms_per_step\n");
    for e in &trainer.history {
        csv.push_str(&format!("{},{},{},{}\n", e.step, e.loss, e.lr, e.ms_per_step));
    }
    let mut f = std::fs::File::create("train_airflow_loss.csv")?;
    f.write_all(csv.as_bytes())?;
    trainer.save_checkpoint(Path::new("train_airflow.bsackpt"))?;
    println!("wrote train_airflow_loss.csv and train_airflow.bsackpt");

    anyhow::ensure!(mse < mse0, "training must improve over random init");
    Ok(())
}
