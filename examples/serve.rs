//! Serving demo: starts the TCP server in-process, fires a client load of
//! concurrent airflow-prediction requests, reports latency percentiles
//! and throughput — the serving-path half of the E2E validation.
//!
//!   make artifacts && cargo run --release --example serve -- [requests] [clients]
//!
//! Backend selection mirrors `bsa serve --backend`: with compiled
//! artifacts present the demo serves the PJRT `fwd_bsa_air_n4096_b1`
//! graph; on an artifact-free host it falls back to the pure-Rust
//! [`NativeBackend`](bsa::backend::NativeBackend) at demo scale (dim 32,
//! 2 blocks, N=1024), so the example runs anywhere. Native weights come
//! from a seeded init here; for trained weights pass a `.bsackpt` param
//! file to `bsa serve --backend native --params <file>` (the flat-binary
//! named-array format documented in `bsa::backend`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use bsa::backend::NativeBackend;
use bsa::config::{ModelConfig, ServeConfig};
use bsa::coordinator::Router;
use bsa::data::generator_for;
use bsa::metrics::LatencyHistogram;
use bsa::runtime::{literal_to_tensor, scalar_i32, Engine};
use bsa::server::{serve, Client};
use bsa::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let requests: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(24);
    let clients: usize = std::env::args().nth(2).map(|s| s.parse()).transpose()?.unwrap_or(3);

    let sc = ServeConfig { workers: 2, ..Default::default() };
    // PJRT needs the engine *and* the demo graphs; a host with only a
    // partial artifact suite must fall back too, so the whole setup is
    // one fallible step.
    let pjrt = (|| -> anyhow::Result<Arc<Router>> {
        let engine = Arc::new(Engine::new(&Engine::default_dir())?);
        println!("PJRT platform: {}", engine.platform());

        // weights: random init (checkpointed weights via `bsa serve --checkpoint`)
        let init = engine.load("init_bsa_air_n1024_b2")?;
        let params: Vec<Tensor> = init
            .run(&[scalar_i32(0)])?
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<_, _>>()?;

        // prefer the XLA-fused forward graph when the bench suite is built
        let fwd = if engine.manifest.get("fwd_bsa_air_n4096_b1_ref").is_ok() {
            "fwd_bsa_air_n4096_b1_ref"
        } else {
            "fwd_bsa_air_n4096_b1"
        };
        println!("serving graph: {fwd} (pjrt)");
        Ok(Arc::new(Router::start_pjrt(engine, fwd, params, sc.clone())?))
    })();
    // `n_points` stays below the backend's N so the ball-tree pad path is
    // exercised, like ShapeNet's 3586 -> 4096.
    let (router, n_points) = match pjrt {
        Ok(router) => (router, 3584usize),
        Err(e) => {
            println!("pjrt path unavailable ({e}); serving the pure-Rust native backend");
            let mc = ModelConfig {
                dim: 32,
                num_heads: 2,
                num_blocks: 2,
                ball_size: 64,
                seq_len: 1024,
                ..Default::default()
            };
            // kernel threads: [serve] native_threads / BSA_NATIVE_THREADS
            // env / hardware parallelism (0 = auto); a pure latency knob —
            // native outputs are bitwise identical at every setting
            let backend =
                Arc::new(NativeBackend::init(0, &mc, 6, 1, 1)?.with_threads(sc.native_threads));
            println!("native kernel threads: {}", backend.threads());
            (Arc::new(Router::start(backend, sc.clone())?), 896usize)
        }
    };

    let addr = "127.0.0.1:17071";
    let stop = Arc::new(AtomicBool::new(false));
    let srv = {
        let (router, stop, addr) = (router.clone(), stop.clone(), addr.to_string());
        std::thread::spawn(move || serve(&addr, router, stop))
    };
    std::thread::sleep(std::time::Duration::from_millis(150));
    println!("server on {addr}; {clients} clients x {requests} requests (N={n_points})");

    let t0 = Instant::now();
    let mut handles = vec![];
    for c in 0..clients {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
            let gen = generator_for("air", c as u64)?;
            let mut client = Client::connect(&addr)?;
            let mut lat = Vec::new();
            for i in 0..requests {
                let car = gen.generate(i as u64, n_points);
                let t = Instant::now();
                let pred = client.predict(&car.coords, &car.features)?;
                lat.push(t.elapsed().as_secs_f64() * 1e6);
                anyhow::ensure!(pred.rows() == n_points, "wrong prediction size");
                anyhow::ensure!(pred.all_finite(), "non-finite prediction");
            }
            Ok(lat)
        }));
    }
    let mut hist = LatencyHistogram::new();
    for h in handles {
        for us in h.join().expect("client thread")? {
            hist.record_us(us);
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let total = requests * clients;
    println!("---");
    println!("served {total} requests in {wall:.1}s = {:.2} req/s", total as f64 / wall);
    println!("client-side latency: {}", hist.summary());
    println!(
        "router: served={} batches={} mean_batch={:.2}",
        router.stats().served,
        router.stats().batches,
        router.stats().mean_batch
    );

    stop.store(true, Ordering::SeqCst);
    srv.join().expect("server thread")?;
    Ok(())
}
