//! Serving demo: starts the TCP server in-process, fires a client load of
//! concurrent airflow-prediction requests, reports latency percentiles
//! and throughput — the serving-path half of the E2E validation.
//!
//!   make artifacts && cargo run --release --example serve -- [requests] [clients]

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use bsa::config::ServeConfig;
use bsa::coordinator::Router;
use bsa::data::generator_for;
use bsa::metrics::LatencyHistogram;
use bsa::runtime::{literal_to_tensor, scalar_i32, Engine};
use bsa::server::{serve, Client};
use bsa::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let requests: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(24);
    let clients: usize = std::env::args().nth(2).map(|s| s.parse()).transpose()?.unwrap_or(3);

    let engine = Arc::new(Engine::new(&Engine::default_dir())?);
    println!("PJRT platform: {}", engine.platform());

    // weights: random init (checkpointed weights via `bsa serve --checkpoint`)
    let init = engine.load("init_bsa_air_n1024_b2")?;
    let params: Vec<Tensor> = init
        .run(&[scalar_i32(0)])?
        .iter()
        .map(literal_to_tensor)
        .collect::<Result<_, _>>()?;

    let sc = ServeConfig { workers: 2, ..Default::default() };
    let addr = "127.0.0.1:17071";
    // prefer the XLA-fused forward graph when the bench suite is built
    let fwd = if engine.manifest.get("fwd_bsa_air_n4096_b1_ref").is_ok() {
        "fwd_bsa_air_n4096_b1_ref"
    } else {
        "fwd_bsa_air_n4096_b1"
    };
    println!("serving graph: {fwd}");
    let router = Arc::new(Router::start(engine, fwd, params, sc)?);
    let stop = Arc::new(AtomicBool::new(false));
    let srv = {
        let (router, stop, addr) = (router.clone(), stop.clone(), addr.to_string());
        std::thread::spawn(move || serve(&addr, router, stop))
    };
    std::thread::sleep(std::time::Duration::from_millis(150));
    println!("server on {addr}; {clients} clients x {requests} requests (N=3584 -> 4096)");

    let t0 = Instant::now();
    let mut handles = vec![];
    for c in 0..clients {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
            let gen = generator_for("air", c as u64)?;
            let mut client = Client::connect(&addr)?;
            let mut lat = Vec::new();
            for i in 0..requests {
                let car = gen.generate(i as u64, 3584);
                let t = Instant::now();
                let pred = client.predict(&car.coords, &car.features)?;
                lat.push(t.elapsed().as_secs_f64() * 1e6);
                anyhow::ensure!(pred.rows() == 3584, "wrong prediction size");
                anyhow::ensure!(pred.all_finite(), "non-finite prediction");
            }
            Ok(lat)
        }));
    }
    let mut hist = LatencyHistogram::new();
    for h in handles {
        for us in h.join().expect("client thread")? {
            hist.record_us(us);
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let total = requests * clients;
    println!("---");
    println!("served {total} requests in {wall:.1}s = {:.2} req/s", total as f64 / wall);
    println!("client-side latency: {}", hist.summary());
    println!(
        "router: served={} batches={} mean_batch={:.2}",
        router.stats().served,
        router.stats().batches,
        router.stats().mean_batch
    );

    stop.store(true, Ordering::SeqCst);
    srv.join().expect("server thread")?;
    Ok(())
}
